//! Deterministic fault injection (ISSUE 7): compute-speed skew, OS-noise
//! pulses, straggler ranks and scheduled rank death — plus the typed
//! failure the detection path surfaces.
//!
//! Everything here is **seeded and deterministic**: a [`FaultPlan`] is a
//! pure description (seed + knobs), expanded per rank into a
//! [`FaultState`] whose draws come from the crate's own
//! [`Rng`](crate::util::Rng) keyed by `(seed, world rank)`. Because the
//! per-rank virtual clock evolves deterministically (host scheduling
//! never reaches it — see DESIGN.md §2), noise pulses keyed off vclock
//! thresholds land at identical virtual times on every run with the same
//! seed: same `FaultPlan` ⇒ bitwise-identical results *and* identical
//! modeled vtime (asserted by `tests/fault.rs`).
//!
//! What each knob injects:
//!
//! - **skew** — a per-rank compute slowdown factor drawn uniformly in
//!   `[1, 1 + skew_frac]`, multiplying every [`compute`] charge. Models
//!   heterogeneous clocks / thermal throttling.
//! - **noise** — OS jitter: exponentially distributed gaps between
//!   pulses, exponentially distributed pulse lengths, charged to vtime
//!   whenever the rank's clock crosses the next pulse threshold. Models
//!   daemons/interrupts stealing cycles (the classic "OS noise"
//!   literature's model).
//! - **stragglers** — explicit per-rank slowdown factors stacked on top
//!   of the drawn skew. Models a persistently slow node.
//! - **dead** — the headline: `(world rank, vtime µs)` pairs. Death is
//!   **cooperative**: it takes effect at the next *injection checkpoint*
//!   ([`ProcEnv::rank_dead`](crate::mpi::env::ProcEnv::rank_dead)), the
//!   way a SIGKILL between collectives would — the rank registers itself
//!   in the cluster-wide dead registry and stops responding (returns from
//!   its closure). Peers blocked on it time out after
//!   [`detect_bound`] of wall clock, consult the registry, and surface
//!   [`RankFailed`] instead of hanging forever. A run with *no* dead
//!   ranks can never spuriously fail: a timeout with an empty registry
//!   just re-arms the wait.
//!
//! Not modeled (DESIGN.md §7): silent data corruption, byzantine
//! behavior, network partitions, or deaths *inside* a bridge transfer
//! (checkpoints sit at collective boundaries). Deaths racing with an
//! in-progress [`shrink`](crate::hybrid::HybridCtx::shrink) — including
//! a dead agreement coordinator and deaths during rebuild — *are*
//! handled since ISSUE 8: the agreement is epoch-tagged and restartable.
//!
//! [`compute`]: crate::mpi::env::ProcEnv::compute
//! [`detect_bound`]: detect_bound

use crate::util::Rng;
use std::fmt;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::time::Duration;

/// Default failure-detection bound (wall-clock µs): how long a bounded
/// wait may stall before consulting the dead registry. Generous enough
/// that a healthy but heavily loaded host never trips it between two
/// registry checks (the check is cheap and the wait re-arms).
pub const DEFAULT_DETECT_BOUND_US: u64 = 20_000;

/// Process-global detection bound, set by
/// [`SimCluster::run`](crate::coordinator::SimCluster::run) from the
/// spec's [`FaultPlan`] (mirrors `PARK_BOUND_US` in [`super::sync`]).
static DETECT_BOUND_US: AtomicU64 = AtomicU64::new(DEFAULT_DETECT_BOUND_US);

/// Install the detection bound for subsequent bounded waits. The engine
/// passes [`FaultPlan::scaled_detect_bound_us`], which stretches the
/// configured bound by the plan's worst straggler factor so a
/// heavily-straggled-but-alive peer is not misdeclared dead.
pub fn set_detect_bound_us(us: u64) {
    DETECT_BOUND_US.store(us.max(1), Ordering::Relaxed);
}

/// The current failure-detection bound as a [`Duration`].
pub fn detect_bound() -> Duration {
    Duration::from_micros(DETECT_BOUND_US.load(Ordering::Relaxed))
}

/// Default for [`FaultPlan::cascade_rounds`]: consecutive
/// detection-bound expiries after which a receive directed at a live
/// source gives up anyway, provided some rank anywhere is registered
/// dead (see [`cascade_rounds`]).
pub const DEFAULT_CASCADE_ROUNDS: u32 = 25;

/// Process-global cascade-round count, installed by the engine from the
/// spec's [`FaultPlan`] (mirrors `DETECT_BOUND_US` above).
static CASCADE_ROUNDS: AtomicU32 = AtomicU32::new(DEFAULT_CASCADE_ROUNDS);

/// Install the cascade-round count for subsequent bounded waits.
pub fn set_cascade_rounds(rounds: u32) {
    CASCADE_ROUNDS.store(rounds.max(2), Ordering::Relaxed);
}

/// Consecutive detection-bound expiries after which a receive directed
/// at a live source gives up anyway, provided some rank anywhere is
/// registered dead: the sender is then presumed stranded behind that
/// failure (it surfaced its own [`RankFailed`] and abandoned the
/// operation, or retreated into a recovery epoch), so the expected
/// message is never coming. The factor keeps the two-tier policy safe:
/// a direct failure is detected in one bound, while the cascade escape
/// needs this many bounds of *continuous* silence — post-shrink steady
/// state (registry permanently non-empty) never accumulates that on a
/// healthy host, because any delivery resets the count. Configurable
/// via [`FaultPlan::with_cascade_rounds`] since ISSUE 8.
pub(crate) fn cascade_rounds() -> u32 {
    CASCADE_ROUNDS.load(Ordering::Relaxed)
}

/// The typed failure surfaced by the detection path: a peer of the
/// operation's communicator died (registered in the cluster dead
/// registry) and the wait's detection bound expired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RankFailed {
    /// World rank of the (lowest-numbered) failed peer.
    pub world_rank: usize,
}

impl fmt::Display for RankFailed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rank {} failed (dead registry, detection bound expired)", self.world_rank)
    }
}

impl std::error::Error for RankFailed {}

/// OS-noise configuration: exponentially distributed pulse gaps and
/// lengths (both means in µs of virtual time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NoiseCfg {
    /// Mean virtual time between pulse starts.
    pub mean_gap_us: f64,
    /// Mean pulse length charged to vtime.
    pub mean_pulse_us: f64,
}

/// A deterministic, seedable fault-injection plan. Attach one to a
/// [`ClusterSpec`](crate::coordinator::ClusterSpec) via
/// [`with_faults`](crate::coordinator::ClusterSpec::with_faults); the
/// engine expands it per rank at thread spawn.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Master seed; per-rank draws are keyed by `(seed, world rank)`.
    pub seed: u64,
    /// Per-rank compute slowdown drawn uniformly in `[1, 1 + skew_frac]`
    /// (0 = no skew).
    pub skew_frac: f64,
    /// OS-noise pulses charged to vtime.
    pub noise: Option<NoiseCfg>,
    /// Designated stragglers: `(world rank, slowdown factor ≥ 1)`.
    pub stragglers: Vec<(usize, f64)>,
    /// Scheduled deaths: `(world rank, vtime µs)` — the rank stops
    /// responding at its first injection checkpoint at or after that
    /// virtual time.
    pub dead: Vec<(usize, f64)>,
    /// Wall-clock failure-detection bound in µs (see [`detect_bound`]).
    pub detect_bound_us: u64,
    /// Detection-bound expiries of continuous silence before the cascade
    /// escape fires (see [`cascade_rounds`]).
    pub cascade_rounds: u32,
    /// Virtual µs charged per *modeled* detection round when a failure
    /// is surfaced (the detection-cost model). `None` charges one
    /// detection bound per round — the time a real timeout-based
    /// detector spends waiting before it declares the peer dead.
    pub detect_cost_us: Option<f64>,
}

impl FaultPlan {
    /// An empty plan under `seed`: no skew, no noise, nobody slow, nobody
    /// dies. Chain the `with_*` builders to arm it.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            skew_frac: 0.0,
            noise: None,
            stragglers: Vec::new(),
            dead: Vec::new(),
            detect_bound_us: DEFAULT_DETECT_BOUND_US,
            cascade_rounds: DEFAULT_CASCADE_ROUNDS,
            detect_cost_us: None,
        }
    }

    /// Draw every rank's compute slowdown uniformly in `[1, 1 + frac]`.
    pub fn with_skew(mut self, frac: f64) -> FaultPlan {
        assert!(frac >= 0.0, "skew fraction must be non-negative");
        self.skew_frac = frac;
        self
    }

    /// Charge exponential OS-noise pulses (`mean_gap_us` between starts,
    /// `mean_pulse_us` long) to every rank's vtime.
    pub fn with_noise(mut self, mean_gap_us: f64, mean_pulse_us: f64) -> FaultPlan {
        assert!(mean_gap_us > 0.0 && mean_pulse_us > 0.0, "noise means must be positive");
        self.noise = Some(NoiseCfg { mean_gap_us, mean_pulse_us });
        self
    }

    /// Mark `world_rank` a straggler: all its compute charges multiply by
    /// `factor` (≥ 1), stacked on the drawn skew.
    pub fn with_straggler(mut self, world_rank: usize, factor: f64) -> FaultPlan {
        assert!(factor >= 1.0, "straggler factor must be >= 1");
        self.stragglers.push((world_rank, factor));
        self
    }

    /// Schedule `world_rank` to die at virtual time `at_us`.
    pub fn with_dead(mut self, world_rank: usize, at_us: f64) -> FaultPlan {
        self.dead.push((world_rank, at_us));
        self
    }

    /// Override the wall-clock failure-detection bound.
    pub fn with_detect_bound_us(mut self, us: u64) -> FaultPlan {
        self.detect_bound_us = us.max(1);
        self
    }

    /// Override the cascade-escape round count (see [`cascade_rounds`];
    /// clamped to ≥ 2 so a direct detection always beats the cascade).
    pub fn with_cascade_rounds(mut self, rounds: u32) -> FaultPlan {
        self.cascade_rounds = rounds.max(2);
        self
    }

    /// Override the virtual detection cost charged per modeled round
    /// when a failure is surfaced (defaults to one detection bound).
    pub fn with_detect_cost_us(mut self, us: f64) -> FaultPlan {
        assert!(us >= 0.0, "detection cost must be non-negative");
        self.detect_cost_us = Some(us);
        self
    }

    /// Worst straggler slowdown in the plan (≥ 1): the factor by which
    /// the wall-clock detection deadline is stretched so a slow-but-alive
    /// peer does not trip the cascade escape (false-positive fix).
    pub fn max_straggler(&self) -> f64 {
        self.stragglers.iter().map(|&(_, f)| f).fold(1.0, f64::max)
    }

    /// The wall-clock detection bound to install: the configured bound
    /// scaled by [`max_straggler`](FaultPlan::max_straggler).
    pub fn scaled_detect_bound_us(&self) -> u64 {
        (self.detect_bound_us as f64 * self.max_straggler()).ceil() as u64
    }

    /// The virtual detection cost per modeled round (the cost-model
    /// resolution of [`detect_cost_us`](FaultPlan::detect_cost_us)).
    pub fn resolved_detect_cost_us(&self) -> f64 {
        self.detect_cost_us.unwrap_or(self.detect_bound_us as f64)
    }

    /// Expand the plan into one rank's runtime state.
    pub(crate) fn state_for(&self, world_rank: usize) -> FaultState {
        // Per-rank stream: mix the rank into the seed so neighboring
        // ranks draw independent skews/noise trains.
        let mut rng = Rng::new(self.seed ^ (world_rank as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut slowdown = if self.skew_frac > 0.0 { 1.0 + self.skew_frac * rng.f64() } else { 1.0 };
        for &(r, f) in &self.stragglers {
            if r == world_rank {
                slowdown *= f;
            }
        }
        let noise = self.noise.map(|cfg| {
            let mut ns = NoiseState { cfg, rng: rng.clone(), next_at: 0.0 };
            ns.next_at = exp_draw(&mut ns.rng, cfg.mean_gap_us);
            ns
        });
        let dead_at = self
            .dead
            .iter()
            .filter(|&&(r, _)| r == world_rank)
            .map(|&(_, at)| at)
            .fold(None, |acc: Option<f64>, at| Some(acc.map_or(at, |a| a.min(at))));
        FaultState { slowdown, noise, dead_at }
    }
}

/// Exponential draw with the given mean (inverse-CDF; `1 - u` keeps the
/// argument of `ln` strictly positive since `u ∈ [0, 1)`).
fn exp_draw(rng: &mut Rng, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

/// Rolling noise-pulse generator: pulse `i` starts when the rank's
/// vclock first crosses `next_at`.
#[derive(Clone, Debug)]
struct NoiseState {
    cfg: NoiseCfg,
    rng: Rng,
    next_at: f64,
}

/// One rank's expanded fault state (private to the MPI substrate; the
/// public face is [`ProcEnv::rank_dead`] /
/// [`ProcEnv::failed_peer`]).
///
/// [`ProcEnv::rank_dead`]: crate::mpi::env::ProcEnv::rank_dead
/// [`ProcEnv::failed_peer`]: crate::mpi::env::ProcEnv::failed_peer
#[derive(Clone, Debug)]
pub(crate) struct FaultState {
    /// Combined skew × straggler compute multiplier (≥ 1).
    pub(crate) slowdown: f64,
    noise: Option<NoiseState>,
    /// Scheduled death vtime, if any.
    pub(crate) dead_at: Option<f64>,
}

impl FaultState {
    /// Extra virtual time to charge for noise pulses whose start
    /// thresholds `vclock` has crossed. Charging the pulse advances the
    /// clock, which may cross further thresholds — the loop settles
    /// because gaps are strictly positive.
    pub(crate) fn noise_due(&mut self, vclock: f64) -> f64 {
        let Some(ns) = self.noise.as_mut() else { return 0.0 };
        let mut extra = 0.0;
        while vclock + extra >= ns.next_at {
            extra += exp_draw(&mut ns.rng, ns.cfg.mean_pulse_us);
            ns.next_at += exp_draw(&mut ns.rng, ns.cfg.mean_gap_us);
        }
        extra
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let st = FaultPlan::seeded(1).state_for(3);
        assert_eq!(st.slowdown, 1.0);
        assert!(st.dead_at.is_none());
        let mut st = st;
        assert_eq!(st.noise_due(1e9), 0.0);
    }

    #[test]
    fn skew_draws_are_deterministic_and_bounded() {
        let plan = FaultPlan::seeded(42).with_skew(0.25);
        for r in 0..16 {
            let a = plan.state_for(r).slowdown;
            let b = plan.state_for(r).slowdown;
            assert_eq!(a, b, "rank {r} draw must be reproducible");
            assert!((1.0..=1.25).contains(&a), "rank {r} slowdown {a}");
        }
        // Different ranks draw different skews (w.h.p. for 16 draws).
        let distinct: std::collections::BTreeSet<u64> =
            (0..16).map(|r| plan.state_for(r).slowdown.to_bits()).collect();
        assert!(distinct.len() > 8);
    }

    #[test]
    fn stragglers_stack_on_skew() {
        let plan = FaultPlan::seeded(7).with_straggler(2, 4.0);
        assert_eq!(plan.state_for(2).slowdown, 4.0);
        assert_eq!(plan.state_for(1).slowdown, 1.0);
    }

    #[test]
    fn noise_pulses_are_deterministic_and_positive() {
        let plan = FaultPlan::seeded(9).with_noise(100.0, 5.0);
        let mut a = plan.state_for(0);
        let mut b = plan.state_for(0);
        let mut va = 0.0;
        let mut vb = 0.0;
        let mut charged = 0.0;
        for step in 0..200 {
            let da = a.noise_due(va);
            let db = b.noise_due(vb);
            assert_eq!(da, db, "step {step}");
            assert!(da >= 0.0);
            charged += da;
            va += 37.0 + da;
            vb += 37.0 + db;
        }
        assert!(charged > 0.0, "noise must fire over 200 × 37 us of vtime");
    }

    #[test]
    fn earliest_death_wins() {
        let plan = FaultPlan::seeded(1).with_dead(5, 900.0).with_dead(5, 300.0);
        assert_eq!(plan.state_for(5).dead_at, Some(300.0));
        assert_eq!(plan.state_for(4).dead_at, None);
    }

    #[test]
    fn rank_failed_displays_the_rank() {
        let e = RankFailed { world_rank: 11 };
        assert!(e.to_string().contains("rank 11"));
    }

    #[test]
    fn cascade_rounds_builder_clamps_and_defaults() {
        assert_eq!(FaultPlan::seeded(1).cascade_rounds, DEFAULT_CASCADE_ROUNDS);
        assert_eq!(FaultPlan::seeded(1).with_cascade_rounds(0).cascade_rounds, 2);
        assert_eq!(FaultPlan::seeded(1).with_cascade_rounds(40).cascade_rounds, 40);
    }

    #[test]
    fn detect_bound_scales_with_worst_straggler() {
        let plan = FaultPlan::seeded(3)
            .with_detect_bound_us(1_000)
            .with_straggler(2, 4.0)
            .with_straggler(5, 2.0);
        assert_eq!(plan.max_straggler(), 4.0);
        assert_eq!(plan.scaled_detect_bound_us(), 4_000);
        assert_eq!(FaultPlan::seeded(3).with_detect_bound_us(1_000).scaled_detect_bound_us(), 1_000);
    }

    #[test]
    fn detect_cost_defaults_to_the_bound() {
        let plan = FaultPlan::seeded(4).with_detect_bound_us(2_000);
        assert_eq!(plan.resolved_detect_cost_us(), 2_000.0);
        assert_eq!(plan.with_detect_cost_us(750.0).resolved_detect_cost_us(), 750.0);
    }
}
