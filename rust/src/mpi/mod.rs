//! The simulated-cluster MPI substrate.
//!
//! This module is the stand-in for the MPI library + physical cluster of
//! the paper's testbeds. Every rank is a real OS thread; every payload
//! really moves (so collective results are bit-checkable); *latency* is
//! virtual, charged by the α-β/LogGP-style model in [`net`].
//!
//! Correspondence with MPI entities:
//!
//! | MPI | here |
//! |---|---|
//! | `MPI_Comm` | [`comm::Communicator`] |
//! | `MPI_Comm_split` / `_split_type` | [`env::ProcEnv::split`] / [`env::ProcEnv::split_type_shared`] |
//! | `MPI_Send` / `MPI_Recv` / `MPI_Sendrecv` | [`env::ProcEnv::send`] / [`env::ProcEnv::recv`] / [`env::ProcEnv::sendrecv`] |
//! | `MPI_Barrier` | [`env::ProcEnv::barrier`] |
//! | `MPI_Win_allocate_shared` | [`env::ProcEnv::win_allocate_shared`] |
//! | `MPI_Win_shared_query` | [`win::SharedWindow::segment`] |
//! | `MPI_Win_sync` | [`env::ProcEnv::win_sync`] |
//! | `MPI_Datatype` | [`datatype::Datatype`] |
//! | `MPI_Op` | [`op::ReduceOp`] |

pub mod comm;
pub mod datatype;
pub mod env;
pub mod fault;
pub mod msg;
pub mod net;
pub mod op;
pub mod pool;
pub mod state;
pub mod sync;
pub mod topo;
pub mod win;

pub use comm::Communicator;
pub use datatype::Datatype;
pub use env::ProcEnv;
pub use fault::{FaultPlan, NoiseCfg, RankFailed};
pub use state::Knobs;
pub use net::NetModel;
pub use op::ReduceOp;
pub use pool::{BufPool, Payload, PoolBuf};
pub use topo::{Placement, Topology};
pub use win::SharedWindow;

/// Reserved tag space: collective ops use `(seq << 16) | op_code`; user
/// point-to-point tags are offset by this bit so the spaces never collide.
pub const USER_TAG_BASE: i64 = 1 << 62;

/// Wildcard source for [`env::ProcEnv::recv`] (`MPI_ANY_SOURCE`).
pub const ANY_SOURCE: Option<usize> = None;
