//! Message envelopes and per-rank mailboxes — the message fabric.
//!
//! Every rank owns one mailbox; senders push envelopes, the owner matches
//! on `(source, tag, communicator)` in FIFO order per matching triple —
//! the non-overtaking rule of MPI point-to-point semantics.
//!
//! Two interchangeable transports implement the mailbox (selected by
//! `ClusterSpec::legacy_fabric`; both deliver bit-identical messages and
//! never affect virtual-time charging — only wall clock differs):
//!
//! - **Fabric** (default, DESIGN.md §5c): the rank's inbox is split into
//!   [`LANES`] source-sharded lanes (`shard = src % LANES`), each backed
//!   by an in-crate bounded lock-free ring ([`Ring`], Vyukov-style
//!   sequence slots, cache-line-padded) with a mutex-protected overflow
//!   spillway and a per-lane posted-message sequence counter. The common
//!   matched-source `recv` drains and scans exactly one lane; `MPI_ANY_SOURCE`
//!   falls back to a full-lane sweep ordered by a per-mailbox arrival
//!   ticket. Blocking uses adaptive spin-then-park [`Doorbell`]s — **one
//!   per lane** plus a summary bell: a matched-source waiter parks on its
//!   lane's bell and is only woken by that lane's traffic, while the
//!   summary bell (rung on every post) keeps `MPI_ANY_SOURCE` waiters
//!   correct. A post to an idle mailbox is two atomic increments — no
//!   lock handoff, no futex syscall, no wakeup of unrelated waiters.
//!   Control-plane posts ([`Mailbox::post_ctrl`]) additionally bypass the
//!   arrival-ticket counter: their receivers are order-insensitive.
//! - **Legacy**: the pre-PR3 single `Mutex<VecDeque>` + condvar queue,
//!   kept so `bench_all` can measure both fabrics in one process.
//!
//! Single-CPU fairness (see DESIGN.md): waits spin only briefly before
//! yielding and then parking — a spinning receiver on a 1-core host would
//! steal the producer's timeslice.

use super::pool::Payload;
use super::sync::Doorbell;
use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// A message in flight. `sent_at` is the sender's virtual clock at
/// injection time; the receiver combines it with the transfer model to
/// compute arrival time.
#[derive(Debug)]
pub struct Msg {
    pub src: usize,
    pub tag: i64,
    pub comm: u64,
    pub sent_at: f64,
    /// Pooled shared payload: fan-out senders (tree broadcasts) clone the
    /// handle instead of the bytes, and the slab recycles into the
    /// sender's [`BufPool`](super::pool::BufPool) when the last reference
    /// drops.
    pub data: Payload,
}

/// Matching criteria for a receive.
#[derive(Clone, Copy, Debug)]
pub struct Matcher {
    /// `None` = `MPI_ANY_SOURCE`.
    pub src: Option<usize>,
    pub tag: i64,
    pub comm: u64,
}

impl Matcher {
    #[inline]
    fn matches(&self, m: &Msg) -> bool {
        m.comm == self.comm && m.tag == self.tag && self.src.map_or(true, |s| s == m.src)
    }
}

/// Source-shard count per mailbox: lane of a message = `src % LANES`.
/// Eight lanes keep the per-mailbox footprint small (every rank pays for
/// them) while making same-lane collisions rare for the neighbor/tree
/// patterns the collectives generate.
pub const LANES: usize = 8;

/// Slots per lane ring. Deliberately small — the ring only has to absorb
/// the *in-flight* burst between two consumer drains; anything beyond
/// spills to the lane's overflow deque without loss or reordering.
const RING_SLOTS: usize = 32;

/// One slot of the bounded MPMC ring: a sequence word (the Vyukov
/// protocol) plus the uninitialized message cell it guards. Padded to a
/// cache line so concurrent producers claiming adjacent positions never
/// false-share a line with the consumer's in-flight dequeue (the `(u64,
/// Msg)` cell is ~72 B, so unpadded slots straddled lines arbitrarily).
#[repr(align(128))]
struct Slot {
    seq: AtomicUsize,
    msg: UnsafeCell<MaybeUninit<(u64, Msg)>>,
}

/// Bounded lock-free ring queue (multi-producer, single-consumer use).
///
/// Protocol: slot `i` accepts an enqueue at position `pos` when
/// `seq == pos`, flips to `pos + 1` when the write lands (visible to the
/// consumer), and back to `pos + RING_SLOTS` after the dequeue (free for
/// the producer one lap later). Producers race on `enqueue_pos` with CAS;
/// the single consumer owns `dequeue_pos` outright.
struct Ring {
    slots: Box<[Slot]>,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
}

// Safety: slot cells are handed off producer→consumer through the
// acquire/release `seq` protocol — exactly one thread ever touches a
// cell between two `seq` transitions. `Msg` itself is `Send`.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new() -> Ring {
        Ring {
            slots: (0..RING_SLOTS)
                .map(|i| Slot { seq: AtomicUsize::new(i), msg: UnsafeCell::new(MaybeUninit::uninit()) })
                .collect(),
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
        }
    }

    /// Multi-producer enqueue; hands the item back if the ring is full.
    fn push(&self, item: (u64, Msg)) -> Result<(), (u64, Msg)> {
        let mask = RING_SLOTS - 1;
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let lag = (seq as isize).wrapping_sub(pos as isize);
            if lag == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.msg.get()).write(item) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if lag < 0 {
                // The slot one lap back is still occupied: full.
                return Err(item);
            } else {
                // Another producer claimed this position; chase the tail.
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Single-consumer dequeue (only the mailbox owner calls this).
    fn pop(&self) -> Option<(u64, Msg)> {
        let mask = RING_SLOTS - 1;
        let pos = self.dequeue_pos.load(Ordering::Relaxed);
        let slot = &self.slots[pos & mask];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq == pos.wrapping_add(1) {
            self.dequeue_pos.store(pos.wrapping_add(1), Ordering::Relaxed);
            let item = unsafe { (*slot.msg.get()).assume_init_read() };
            slot.seq.store(pos.wrapping_add(RING_SLOTS), Ordering::Release);
            Some(item)
        } else {
            None
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        // Release payload handles of undelivered messages.
        while self.pop().is_some() {}
    }
}

/// One source shard of a fabric mailbox.
struct Lane {
    ring: Ring,
    /// Spillway for ring-full bursts. Once `overflowed` is set, *all*
    /// producers of this lane divert here (checked before the ring) until
    /// the consumer drains it — that total order is what preserves
    /// per-source FIFO across the ring/overflow boundary.
    overflow: Mutex<VecDeque<(u64, Msg)>>,
    overflowed: AtomicBool,
    /// Per-lane sequence counter: messages ever posted to this lane.
    /// `posted - taken` is the lane's logical depth without touching any
    /// queue lock.
    posted: AtomicU64,
    /// Messages the owner consumed from this lane.
    taken: AtomicU64,
    /// Drained-but-unmatched messages, staged for matching. Owner-only by
    /// construction (one receiver thread per mailbox); the uncontended
    /// mutex exists to keep the type `Sync` without an unsafe owner
    /// assertion, and costs one uncontended CAS to take.
    pending: Mutex<VecDeque<(u64, Msg)>>,
    /// Per-lane doorbell (deferred from PR 3): a matched-source waiter
    /// parks on *its* lane's bell, so it no longer wakes — and rescans —
    /// on every other lane's traffic. `MPI_ANY_SOURCE` correctness lives
    /// on the mailbox's summary bell, which every post also rings.
    bell: Doorbell,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            ring: Ring::new(),
            overflow: Mutex::new(VecDeque::new()),
            overflowed: AtomicBool::new(false),
            posted: AtomicU64::new(0),
            taken: AtomicU64::new(0),
            pending: Mutex::new(VecDeque::new()),
            bell: Doorbell::new(),
        }
    }

    /// Move everything deliverable into `pending`. Callers pass in the
    /// lane's locked `pending` deque — holding that lock is what
    /// serializes ring consumption (the ring's `pop` is single-consumer),
    /// even for the occasional non-owner `probe`. Ring first, then — if
    /// producers spilled — the overflow, then the ring once more for
    /// entries that raced in after the flag cleared. Within one source
    /// the ring entries always predate the overflow entries (producers
    /// divert to the overflow *before* it is drained and only return to
    /// the ring after), so per-source FIFO survives the spill.
    fn drain_into(&self, pending: &mut VecDeque<(u64, Msg)>) {
        loop {
            while let Some(item) = self.ring.pop() {
                pending.push_back(item);
            }
            if !self.overflowed.load(Ordering::Acquire) {
                return;
            }
            let mut of = self.overflow.lock().unwrap();
            while let Some(item) = of.pop_front() {
                pending.push_back(item);
            }
            // Cleared while still holding the lock: a producer blocked on
            // it re-checks the flag and returns to the ring path.
            self.overflowed.store(false, Ordering::Release);
        }
    }
}

/// Ticket carried by control-plane posts ([`Mailbox::post_ctrl`]): they
/// skip the arrival counter entirely. `MPI_ANY_SOURCE` control receivers
/// (split/window mechanics) index replies by source, so the only effect
/// is that a control message never beats a data message in an any-source
/// sweep — and the control plane saves the shared `fetch_add`.
const CTRL_TICKET: u64 = u64::MAX;

/// The sharded, mostly-lock-free transport (DESIGN.md §5c).
struct Fabric {
    lanes: [Lane; LANES],
    /// Arrival tickets: total order over data-plane posts to this
    /// mailbox, used by the `MPI_ANY_SOURCE` sweep to pick the earliest
    /// match across lanes (and by nothing else — matched-source receives
    /// never read it). One relaxed `fetch_add` per data post; control
    /// posts bypass it ([`CTRL_TICKET`]).
    ticket: AtomicU64,
    /// Summary bell: rung on *every* post (data and control), waited on
    /// by `MPI_ANY_SOURCE` receivers only. Matched-source receivers wait
    /// on their lane's bell instead.
    summary: Doorbell,
}

impl Fabric {
    fn new() -> Fabric {
        Fabric {
            lanes: std::array::from_fn(|_| Lane::new()),
            ticket: AtomicU64::new(0),
            summary: Doorbell::new(),
        }
    }

    fn post_ticketed(&self, t: u64, msg: Msg) {
        let lane = &self.lanes[msg.src % LANES];
        lane.posted.fetch_add(1, Ordering::Relaxed);
        let mut item = (t, msg);
        if lane.overflowed.load(Ordering::Acquire) {
            let mut of = lane.overflow.lock().unwrap();
            if lane.overflowed.load(Ordering::Relaxed) {
                of.push_back(item);
                drop(of);
                lane.bell.ring();
                self.summary.ring();
                return;
            }
            // Consumer drained the spillway while we waited for the lock;
            // the ring is the ordered destination again.
            drop(of);
        }
        if let Err(back) = lane.ring.push(item) {
            item = back;
            let mut of = lane.overflow.lock().unwrap();
            lane.overflowed.store(true, Ordering::Release);
            of.push_back(item);
        }
        lane.bell.ring();
        self.summary.ring();
    }

    fn post(&self, msg: Msg) {
        let t = self.ticket.fetch_add(1, Ordering::Relaxed);
        self.post_ticketed(t, msg);
    }

    fn post_ctrl(&self, msg: Msg) {
        self.post_ticketed(CTRL_TICKET, msg);
    }

    fn recv(&self, m: Matcher) -> Msg {
        match m.src {
            Some(s) => self.recv_matched(s, m),
            None => self.recv_any(m),
        }
    }

    /// [`Fabric::recv`] with a hard wall-clock deadline: `None` once
    /// `deadline` passes without a match (failure detection — a receive
    /// whose source died must not park forever). Every individual bell
    /// wait is already bounded by the park timeout, so checking the
    /// deadline between wait rounds bounds total blocking to
    /// `deadline + park_bound`.
    fn recv_deadline(&self, m: Matcher, deadline: std::time::Instant) -> Option<Msg> {
        match m.src {
            Some(src) => {
                let lane = &self.lanes[src % LANES];
                let mut scanned = 0usize;
                loop {
                    let epoch = lane.bell.epoch();
                    let mut pending = lane.pending.lock().unwrap();
                    lane.drain_into(&mut pending);
                    if let Some(pos) =
                        pending.iter().skip(scanned).position(|(_, msg)| m.matches(msg))
                    {
                        let (_, msg) = pending.remove(scanned + pos).unwrap();
                        drop(pending);
                        lane.taken.fetch_add(1, Ordering::Relaxed);
                        return Some(msg);
                    }
                    scanned = pending.len();
                    drop(pending);
                    if std::time::Instant::now() >= deadline {
                        return None;
                    }
                    lane.bell.wait_change(epoch);
                }
            }
            None => loop {
                let epoch = self.summary.epoch();
                let mut best: Option<(u64, usize, usize)> = None;
                for (li, lane) in self.lanes.iter().enumerate() {
                    let mut pending = lane.pending.lock().unwrap();
                    lane.drain_into(&mut pending);
                    for (idx, (t, msg)) in pending.iter().enumerate() {
                        if m.matches(msg) {
                            if best.map_or(true, |(bt, _, _)| *t < bt) {
                                best = Some((*t, li, idx));
                            }
                            break;
                        }
                    }
                }
                if let Some((_, li, idx)) = best {
                    let lane = &self.lanes[li];
                    let (_, msg) = lane.pending.lock().unwrap().remove(idx).unwrap();
                    lane.taken.fetch_add(1, Ordering::Relaxed);
                    return Some(msg);
                }
                if std::time::Instant::now() >= deadline {
                    return None;
                }
                self.summary.wait_change(epoch);
            },
        }
    }

    /// Matched-source receive: touches exactly one lane. The scanned
    /// prefix resumes across wakeups (only the owner removes messages and
    /// drains only append, so a scanned prefix can never start matching
    /// later) — without this, deep queues make a blocked receive
    /// quadratic in queue depth.
    fn recv_matched(&self, src: usize, m: Matcher) -> Msg {
        let lane = &self.lanes[src % LANES];
        let mut scanned = 0usize;
        loop {
            // Per-lane bell: only this lane's posts wake (and rescan) us.
            let epoch = lane.bell.epoch();
            let mut pending = lane.pending.lock().unwrap();
            lane.drain_into(&mut pending);
            if let Some(pos) = pending.iter().skip(scanned).position(|(_, msg)| m.matches(msg)) {
                let (_, msg) = pending.remove(scanned + pos).unwrap();
                drop(pending);
                lane.taken.fetch_add(1, Ordering::Relaxed);
                return msg;
            }
            scanned = pending.len();
            drop(pending);
            lane.bell.wait_change(epoch);
        }
    }

    /// `MPI_ANY_SOURCE`: sweep every lane and take the matching message
    /// with the earliest arrival ticket among the per-lane first matches.
    /// Per-source FIFO holds (a source's first match in its lane is its
    /// earliest); across sources the ticket reproduces the legacy
    /// fabric's global arrival order whenever posts are ordered at all.
    fn recv_any(&self, m: Matcher) -> Msg {
        loop {
            let epoch = self.summary.epoch();
            let mut best: Option<(u64, usize, usize)> = None; // (ticket, lane, index)
            for (li, lane) in self.lanes.iter().enumerate() {
                let mut pending = lane.pending.lock().unwrap();
                lane.drain_into(&mut pending);
                for (idx, (t, msg)) in pending.iter().enumerate() {
                    if m.matches(msg) {
                        if best.map_or(true, |(bt, _, _)| *t < bt) {
                            best = Some((*t, li, idx));
                        }
                        break;
                    }
                }
            }
            if let Some((_, li, idx)) = best {
                // Indices stay valid: only this (owner) thread removes,
                // concurrent drains only append behind `idx`.
                let lane = &self.lanes[li];
                let (_, msg) = lane.pending.lock().unwrap().remove(idx).unwrap();
                lane.taken.fetch_add(1, Ordering::Relaxed);
                return msg;
            }
            self.summary.wait_change(epoch);
        }
    }

    fn probe(&self, m: Matcher) -> bool {
        let probe_lane = |lane: &Lane| {
            let mut pending = lane.pending.lock().unwrap();
            lane.drain_into(&mut pending);
            pending.iter().any(|(_, msg)| m.matches(msg))
        };
        match m.src {
            Some(s) => probe_lane(&self.lanes[s % LANES]),
            None => self.lanes.iter().any(probe_lane),
        }
    }

    fn depth(&self) -> usize {
        // Saturating: `posted` and `taken` are independent relaxed
        // counters, so a racing reader may transiently observe an
        // in-progress delivery's `taken` before its `posted`.
        self.lanes
            .iter()
            .map(|l| {
                l.posted.load(Ordering::Relaxed).saturating_sub(l.taken.load(Ordering::Relaxed))
                    as usize
            })
            .sum()
    }
}

/// The pre-PR3 transport: one contended `Mutex<VecDeque>` + condvar.
struct LegacyQueue {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl LegacyQueue {
    fn new() -> LegacyQueue {
        LegacyQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn post(&self, msg: Msg) {
        let mut q = self.q.lock().unwrap();
        q.push_back(msg);
        // One owner thread per mailbox — notify_one is sufficient.
        self.cv.notify_one();
    }

    /// First match in queue order = FIFO per (src, tag, comm); resumes
    /// scanning past the already-scanned prefix on each wakeup.
    fn recv(&self, m: Matcher) -> Msg {
        let mut q = self.q.lock().unwrap();
        let mut scanned = 0usize;
        loop {
            if let Some(pos) = q.iter().skip(scanned).position(|msg| m.matches(msg)) {
                return q.remove(scanned + pos).unwrap();
            }
            scanned = q.len();
            q = self.cv.wait(q).unwrap();
        }
    }

    /// [`LegacyQueue::recv`] with a hard wall-clock deadline (condvar
    /// timed waits); `None` on expiry without a match.
    fn recv_deadline(&self, m: Matcher, deadline: std::time::Instant) -> Option<Msg> {
        let mut q = self.q.lock().unwrap();
        let mut scanned = 0usize;
        loop {
            if let Some(pos) = q.iter().skip(scanned).position(|msg| m.matches(msg)) {
                return Some(q.remove(scanned + pos).unwrap());
            }
            scanned = q.len();
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timeout) = self.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    fn probe(&self, m: Matcher) -> bool {
        self.q.lock().unwrap().iter().any(|msg| m.matches(msg))
    }

    fn depth(&self) -> usize {
        self.q.lock().unwrap().len()
    }
}

enum Transport {
    Fabric(Fabric),
    Legacy(LegacyQueue),
}

/// One rank's incoming queue (see the module docs for the two transports).
pub struct Mailbox {
    inner: Transport,
}

impl Default for Mailbox {
    fn default() -> Self {
        Mailbox::new()
    }
}

impl Mailbox {
    /// The sharded lock-free fabric (default).
    pub fn new() -> Mailbox {
        Mailbox { inner: Transport::Fabric(Fabric::new()) }
    }

    /// The pre-PR3 mutex+condvar transport.
    pub fn legacy() -> Mailbox {
        Mailbox { inner: Transport::Legacy(LegacyQueue::new()) }
    }

    /// Transport selected by `ClusterSpec::legacy_fabric`.
    pub fn with_mode(legacy: bool) -> Mailbox {
        if legacy {
            Mailbox::legacy()
        } else {
            Mailbox::new()
        }
    }

    /// Deliver a message (called by the sender's thread).
    pub fn post(&self, msg: Msg) {
        match &self.inner {
            Transport::Fabric(f) => f.post(msg),
            Transport::Legacy(l) => l.post(msg),
        }
    }

    /// Deliver a control-plane message: same lanes, same bells, but no
    /// arrival ticket ([`CTRL_TICKET`]) — the out-of-band mechanics
    /// (splits, window allocation) identify `ANY_SOURCE` replies by
    /// source, so the data plane's total arrival order is not needed.
    /// On the legacy transport this is identical to [`Mailbox::post`].
    pub fn post_ctrl(&self, msg: Msg) {
        match &self.inner {
            Transport::Fabric(f) => f.post_ctrl(msg),
            Transport::Legacy(l) => l.post(msg),
        }
    }

    /// Block until a matching message exists, remove and return it.
    /// FIFO per (src, tag, comm) — the MPI non-overtaking rule.
    pub fn recv(&self, m: Matcher) -> Msg {
        match &self.inner {
            Transport::Fabric(f) => f.recv(m),
            Transport::Legacy(l) => l.recv(m),
        }
    }

    /// [`Mailbox::recv`] with a hard wall-clock deadline: `Some(msg)` on
    /// a match, `None` once `deadline` passes without one. The
    /// fault-injection layer's receive path — the caller consults the
    /// dead registry on `None` and either re-arms or surfaces the failure.
    pub fn recv_deadline(&self, m: Matcher, deadline: std::time::Instant) -> Option<Msg> {
        match &self.inner {
            Transport::Fabric(f) => f.recv_deadline(m, deadline),
            Transport::Legacy(l) => l.recv_deadline(m, deadline),
        }
    }

    /// Non-blocking probe: does a matching message exist? (Owner-side
    /// operation, like `probe`.)
    pub fn probe(&self, m: Matcher) -> bool {
        match &self.inner {
            Transport::Fabric(f) => f.probe(m),
            Transport::Legacy(l) => l.probe(m),
        }
    }

    /// Remove and discard every currently-matching message; returns how
    /// many were dropped. Owner-side hygiene for restartable protocols:
    /// after an epoch of the shrink agreement completes, duplicate
    /// requests a child re-sent (and replies a restarted coordinator
    /// superseded) are swept so they can never alias a later epoch's
    /// traffic. Not a receive — nothing is charged, nothing is returned.
    pub fn drain(&self, m: Matcher) -> usize {
        let mut n = 0;
        // A deadline already in the past turns `recv_deadline` into a
        // single non-blocking match attempt on both transports.
        let now = std::time::Instant::now();
        while self.recv_deadline(m, now).is_some() {
            n += 1;
        }
        n
    }

    /// Current queue depth (diagnostics).
    pub fn depth(&self) -> usize {
        match &self.inner {
            Transport::Fabric(f) => f.depth(),
            Transport::Legacy(l) => l.depth(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn msg(src: usize, tag: i64, comm: u64, byte: u8) -> Msg {
        Msg { src, tag, comm, sent_at: 0.0, data: Payload::from_vec(vec![byte]) }
    }

    /// Every semantic test runs on both transports.
    fn both(f: impl Fn(Mailbox)) {
        f(Mailbox::new());
        f(Mailbox::legacy());
    }

    #[test]
    fn fifo_per_matching_triple() {
        both(|mb| {
            mb.post(msg(1, 7, 0, 0xAA));
            mb.post(msg(1, 7, 0, 0xBB));
            let m = Matcher { src: Some(1), tag: 7, comm: 0 };
            assert_eq!(mb.recv(m).data[0], 0xAA);
            assert_eq!(mb.recv(m).data[0], 0xBB);
        });
    }

    #[test]
    fn tag_and_comm_are_selective() {
        both(|mb| {
            mb.post(msg(1, 1, 0, 1));
            mb.post(msg(1, 2, 0, 2));
            mb.post(msg(1, 1, 9, 3));
            assert_eq!(mb.recv(Matcher { src: Some(1), tag: 2, comm: 0 }).data[0], 2);
            assert_eq!(mb.recv(Matcher { src: Some(1), tag: 1, comm: 9 }).data[0], 3);
            assert_eq!(mb.recv(Matcher { src: Some(1), tag: 1, comm: 0 }).data[0], 1);
            assert_eq!(mb.depth(), 0);
        });
    }

    #[test]
    fn any_source_matches_first_arrival() {
        both(|mb| {
            mb.post(msg(5, 3, 0, 50));
            mb.post(msg(2, 3, 0, 20));
            let got = mb.recv(Matcher { src: None, tag: 3, comm: 0 });
            assert_eq!(got.src, 5);
        });
    }

    #[test]
    fn any_source_first_arrival_within_one_lane() {
        // Sources 1 and 1 + LANES share a lane; ticket order still wins.
        both(|mb| {
            mb.post(msg(1 + LANES, 3, 0, 9));
            mb.post(msg(1, 3, 0, 1));
            assert_eq!(mb.recv(Matcher { src: None, tag: 3, comm: 0 }).src, 1 + LANES);
            assert_eq!(mb.recv(Matcher { src: None, tag: 3, comm: 0 }).src, 1);
        });
    }

    #[test]
    fn blocking_recv_wakes_on_post() {
        both(|mb| {
            let mb = Arc::new(mb);
            let mb2 = mb.clone();
            let h =
                std::thread::spawn(move || mb2.recv(Matcher { src: Some(0), tag: 1, comm: 0 }).data[0]);
            std::thread::sleep(std::time::Duration::from_millis(20));
            mb.post(msg(0, 1, 0, 42));
            assert_eq!(h.join().unwrap(), 42);
        });
    }

    #[test]
    fn waiting_recv_skips_scanned_prefix_and_still_matches() {
        both(|mb| {
            let mb = Arc::new(mb);
            let mb2 = mb.clone();
            let h = std::thread::spawn(move || {
                mb2.recv(Matcher { src: Some(0), tag: 9, comm: 0 }).data[0]
            });
            // Bury the eventual match under non-matching traffic posted while
            // the receiver waits (each post re-wakes it mid-scan). Source 8
            // shares lane 0 with source 0, so the fabric's lane scan is
            // exercised too, and the burst exceeds the ring capacity.
            for i in 0..100u8 {
                mb.post(msg(8, 1, 0, i));
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            mb.post(msg(0, 9, 0, 77));
            assert_eq!(h.join().unwrap(), 77);
            assert_eq!(mb.depth(), 100, "non-matching messages stay queued");
        });
    }

    #[test]
    fn ring_overflow_preserves_per_source_fifo() {
        // Far more than RING_SLOTS messages from two sources sharing a
        // lane, posted interleaved: each source's stream must come out in
        // order, and nothing may be lost.
        both(|mb| {
            for i in 0..200u8 {
                mb.post(msg(2, 5, 0, i));
                mb.post(msg(2 + LANES, 5, 0, i));
            }
            assert_eq!(mb.depth(), 400);
            for i in 0..200u8 {
                assert_eq!(mb.recv(Matcher { src: Some(2), tag: 5, comm: 0 }).data[0], i);
            }
            for i in 0..200u8 {
                assert_eq!(
                    mb.recv(Matcher { src: Some(2 + LANES), tag: 5, comm: 0 }).data[0],
                    i
                );
            }
            assert_eq!(mb.depth(), 0);
        });
    }

    #[test]
    fn ctrl_posts_match_and_keep_fifo_without_tickets() {
        both(|mb| {
            mb.post_ctrl(msg(1, 7, 0, 0x11));
            mb.post_ctrl(msg(1, 7, 0, 0x22));
            mb.post(msg(1, 7, 0, 0x33));
            let m = Matcher { src: Some(1), tag: 7, comm: 0 };
            assert_eq!(mb.recv(m).data[0], 0x11, "ctrl stream stays FIFO per source");
            assert_eq!(mb.recv(m).data[0], 0x22);
            assert_eq!(mb.recv(m).data[0], 0x33, "data after ctrl still in order");
            assert_eq!(mb.depth(), 0);
        });
    }

    #[test]
    fn ctrl_posts_wake_any_source_waiters() {
        both(|mb| {
            let mb = Arc::new(mb);
            let mb2 = mb.clone();
            let h =
                std::thread::spawn(move || mb2.recv(Matcher { src: None, tag: 4, comm: 0 }).data[0]);
            std::thread::sleep(std::time::Duration::from_millis(20));
            mb.post_ctrl(msg(6, 4, 0, 99));
            assert_eq!(h.join().unwrap(), 99);
        });
    }

    #[test]
    fn matched_waiter_not_woken_needlessly_still_correct() {
        // Traffic on other lanes must not prevent (or break) a matched
        // receive on lane 2; the waiter parks on its own lane's bell.
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || {
            mb2.recv(Matcher { src: Some(2), tag: 5, comm: 0 }).data[0]
        });
        for i in 0..50u8 {
            mb.post(msg(1, 5, 0, i)); // lane 1 noise
            mb.post(msg(3, 5, 0, i)); // lane 3 noise
        }
        std::thread::sleep(std::time::Duration::from_millis(15));
        mb.post(msg(2, 5, 0, 42));
        assert_eq!(h.join().unwrap(), 42);
        assert_eq!(mb.depth(), 100);
    }

    #[test]
    fn recv_deadline_times_out_then_matches() {
        both(|mb| {
            let m = Matcher { src: Some(1), tag: 7, comm: 0 };
            let start = std::time::Instant::now();
            let deadline = start + std::time::Duration::from_millis(20);
            assert!(mb.recv_deadline(m, deadline).is_none());
            assert!(start.elapsed() >= std::time::Duration::from_millis(20));
            mb.post(msg(1, 7, 0, 0x5A));
            let far = std::time::Instant::now() + std::time::Duration::from_secs(5);
            assert_eq!(mb.recv_deadline(m, far).unwrap().data[0], 0x5A);
            // ANY_SOURCE flavor times out and matches too.
            let any = Matcher { src: None, tag: 8, comm: 0 };
            assert!(mb
                .recv_deadline(any, std::time::Instant::now() + std::time::Duration::from_millis(10))
                .is_none());
            mb.post(msg(3, 8, 0, 0x6B));
            let far = std::time::Instant::now() + std::time::Duration::from_secs(5);
            assert_eq!(mb.recv_deadline(any, far).unwrap().data[0], 0x6B);
        });
    }

    #[test]
    fn probe_does_not_consume() {
        both(|mb| {
            let m = Matcher { src: Some(1), tag: 1, comm: 0 };
            assert!(!mb.probe(m));
            mb.post(msg(1, 1, 0, 9));
            assert!(mb.probe(m));
            assert_eq!(mb.depth(), 1);
        });
    }

    #[test]
    fn concurrent_producers_one_lane_nothing_lost() {
        // Four producer threads hammer sources that all collide in lane 3
        // while the owner drains with ANY_SOURCE; every message must
        // arrive exactly once and per-source streams stay ordered.
        let mb = Arc::new(Mailbox::new());
        const PER: usize = 500;
        const PRODUCERS: usize = 4;
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let mb = mb.clone();
                std::thread::spawn(move || {
                    let src = 3 + p * LANES; // all in lane 3
                    for i in 0..PER {
                        mb.post(msg(src, 1, 0, (i % 251) as u8));
                    }
                })
            })
            .collect();
        let mut counts = vec![0usize; PRODUCERS];
        for _ in 0..PER * PRODUCERS {
            let got = mb.recv(Matcher { src: None, tag: 1, comm: 0 });
            let p = (got.src - 3) / LANES;
            assert_eq!(got.data[0], (counts[p] % 251) as u8, "per-source FIFO broken");
            counts[p] += 1;
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counts, vec![PER; PRODUCERS]);
        assert_eq!(mb.depth(), 0);
    }
}
