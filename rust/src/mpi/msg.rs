//! Message envelopes and per-rank mailboxes.
//!
//! Every rank owns one mailbox; senders push envelopes, the owner matches
//! on `(source, tag, communicator)` in FIFO order per matching triple —
//! the non-overtaking rule of MPI point-to-point semantics. Blocking is
//! condvar-based: the host has a single CPU, so spinning would steal the
//! producer's timeslice (see DESIGN.md).

use super::pool::Payload;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// A message in flight. `sent_at` is the sender's virtual clock at
/// injection time; the receiver combines it with the transfer model to
/// compute arrival time.
#[derive(Debug)]
pub struct Msg {
    pub src: usize,
    pub tag: i64,
    pub comm: u64,
    pub sent_at: f64,
    /// Pooled shared payload: fan-out senders (tree broadcasts) clone the
    /// handle instead of the bytes, and the slab recycles into the
    /// sender's [`BufPool`](super::pool::BufPool) when the last reference
    /// drops.
    pub data: Payload,
}

/// Matching criteria for a receive.
#[derive(Clone, Copy, Debug)]
pub struct Matcher {
    /// `None` = `MPI_ANY_SOURCE`.
    pub src: Option<usize>,
    pub tag: i64,
    pub comm: u64,
}

impl Matcher {
    #[inline]
    fn matches(&self, m: &Msg) -> bool {
        m.comm == self.comm && m.tag == self.tag && self.src.map_or(true, |s| s == m.src)
    }
}

/// One rank's incoming queue.
#[derive(Default)]
pub struct Mailbox {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl Mailbox {
    pub fn new() -> Mailbox {
        Mailbox { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    /// Deliver a message (called by the sender's thread).
    pub fn post(&self, msg: Msg) {
        let mut q = self.q.lock().unwrap();
        q.push_back(msg);
        // One owner thread per mailbox — notify_one is sufficient.
        self.cv.notify_one();
    }

    /// Block until a matching message exists, remove and return it.
    /// First match in queue order = FIFO per (src, tag, comm).
    ///
    /// Each wait resumes scanning where the previous pass stopped: only
    /// the owner thread removes messages and posts only append, so a
    /// scanned prefix can never start matching later — without this,
    /// deep queues make a blocked receive quadratic in queue depth.
    pub fn recv(&self, m: Matcher) -> Msg {
        let mut q = self.q.lock().unwrap();
        let mut scanned = 0usize;
        loop {
            if let Some(pos) = q.iter().skip(scanned).position(|msg| m.matches(msg)) {
                return q.remove(scanned + pos).unwrap();
            }
            scanned = q.len();
            q = self.cv.wait(q).unwrap();
        }
    }

    /// Non-blocking probe: does a matching message exist?
    pub fn probe(&self, m: Matcher) -> bool {
        self.q.lock().unwrap().iter().any(|msg| m.matches(msg))
    }

    /// Current queue depth (diagnostics).
    pub fn depth(&self) -> usize {
        self.q.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn msg(src: usize, tag: i64, comm: u64, byte: u8) -> Msg {
        Msg { src, tag, comm, sent_at: 0.0, data: Payload::from_vec(vec![byte]) }
    }

    #[test]
    fn fifo_per_matching_triple() {
        let mb = Mailbox::new();
        mb.post(msg(1, 7, 0, 0xAA));
        mb.post(msg(1, 7, 0, 0xBB));
        let m = Matcher { src: Some(1), tag: 7, comm: 0 };
        assert_eq!(mb.recv(m).data[0], 0xAA);
        assert_eq!(mb.recv(m).data[0], 0xBB);
    }

    #[test]
    fn tag_and_comm_are_selective() {
        let mb = Mailbox::new();
        mb.post(msg(1, 1, 0, 1));
        mb.post(msg(1, 2, 0, 2));
        mb.post(msg(1, 1, 9, 3));
        assert_eq!(mb.recv(Matcher { src: Some(1), tag: 2, comm: 0 }).data[0], 2);
        assert_eq!(mb.recv(Matcher { src: Some(1), tag: 1, comm: 9 }).data[0], 3);
        assert_eq!(mb.recv(Matcher { src: Some(1), tag: 1, comm: 0 }).data[0], 1);
        assert_eq!(mb.depth(), 0);
    }

    #[test]
    fn any_source_matches_first_arrival() {
        let mb = Mailbox::new();
        mb.post(msg(5, 3, 0, 50));
        mb.post(msg(2, 3, 0, 20));
        let got = mb.recv(Matcher { src: None, tag: 3, comm: 0 });
        assert_eq!(got.src, 5);
    }

    #[test]
    fn blocking_recv_wakes_on_post() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || mb2.recv(Matcher { src: Some(0), tag: 1, comm: 0 }).data[0]);
        std::thread::sleep(std::time::Duration::from_millis(20));
        mb.post(msg(0, 1, 0, 42));
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn waiting_recv_skips_scanned_prefix_and_still_matches() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h =
            std::thread::spawn(move || mb2.recv(Matcher { src: Some(0), tag: 9, comm: 0 }).data[0]);
        // Bury the eventual match under non-matching traffic posted while
        // the receiver waits (each post re-wakes it mid-scan).
        for i in 0..100u8 {
            mb.post(msg(1, 1, 0, i));
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        mb.post(msg(0, 9, 0, 77));
        assert_eq!(h.join().unwrap(), 77);
        assert_eq!(mb.depth(), 100, "non-matching messages stay queued");
    }

    #[test]
    fn probe_does_not_consume() {
        let mb = Mailbox::new();
        let m = Matcher { src: Some(1), tag: 1, comm: 0 };
        assert!(!mb.probe(m));
        mb.post(msg(1, 1, 0, 9));
        assert!(mb.probe(m));
        assert_eq!(mb.depth(), 1);
    }
}
