//! The latency/bandwidth cost model (LogGP/α-β family).
//!
//! Virtual time is charged in **microseconds**. Two transfer tiers exist:
//!
//! - **inter-node**: the fabric (InfiniBand on "Vulcan", Aries dragonfly on
//!   "Hazel Hen"): `α_net + β_net·bytes`, plus a rendezvous handshake `α`
//!   above the eager threshold;
//! - **intra-node (pure-MPI p2p)**: the MPI library's double copy through a
//!   shared staging buffer — `α_shm + 2·β_mem·bytes`. This is precisely the
//!   on-node overhead the paper's hybrid collectives eliminate: a hybrid
//!   rank touches the shared window with plain load/store at `β_mem·bytes`
//!   (single copy) and no per-peer α.
//!
//! Synchronization costs (§4.5): a dissemination-barrier round costs
//! `barrier_round_us`; the spinning release/observe pair costs
//! `spin_release_us`/`spin_poll_us`; `MPI_Win_sync` costs `win_sync_us`
//! (a processor memory fence).
//!
//! The constants are *calibrated*, not measured on the paper's silicon:
//! they are chosen so the published magnitudes (Table 2, Figs. 12–16) land
//! in the right decade and every published crossover (2 KB method cutoff,
//! 128 B allreduce sign flip, 512 KB pipeline dip, 2 KB / 362 KB / 9 KB
//! algorithm switch points) is reproduced. See DESIGN.md §2.

/// One transfer tier of the fabric.
#[derive(Clone, Copy, Debug)]
pub struct LinkParams {
    /// Per-message latency (µs).
    pub alpha_us: f64,
    /// Per-byte time (µs/B) = 1 / bandwidth.
    pub beta_us_per_byte: f64,
    /// Eager→rendezvous protocol switch (bytes).
    pub eager_max: usize,
    /// Extra handshake latency charged above `eager_max` (µs).
    pub rndv_alpha_us: f64,
}

impl LinkParams {
    /// Transfer time of `bytes` over this link (µs).
    #[inline]
    pub fn transfer(&self, bytes: usize) -> f64 {
        let rndv = if bytes > self.eager_max { self.rndv_alpha_us } else { 0.0 };
        self.alpha_us + rndv + self.beta_us_per_byte * bytes as f64
    }
}

/// Full cost model for one cluster.
#[derive(Clone, Debug)]
pub struct NetModel {
    /// Inter-node fabric.
    pub internode: LinkParams,
    /// Independent NIC lanes (rails) per node. Each lane serializes its
    /// own injections at `internode.beta_us_per_byte`; distinct lanes
    /// overlap. One MPI process drives exactly one lane at a time (the
    /// arXiv 2401.16551 observation: a single endpoint cannot saturate a
    /// multi-rail node), so the default binding is lane 0 for every rank
    /// — all pre-existing traffic patterns serialize exactly as under the
    /// old single-NIC model — and only the multi-leader hybrid bridge
    /// ([`crate::hybrid::HybridCtx`]) spreads its leaders across lanes
    /// (leader `j` → lane `j % nic_lanes`, the arXiv 2007.06892 design).
    pub nic_lanes: usize,
    /// Per-message latency of intra-node (pure-MPI) p2p (µs).
    pub alpha_shm_us: f64,
    /// Single memory-copy cost (µs/B); intra-node p2p pays it twice.
    pub beta_mem_us_per_byte: f64,
    /// Number of copies an intra-node pure-MPI message makes through the
    /// library's staging buffer (2 = copy-in + copy-out, CMA would be 1).
    pub shm_copies: f64,
    /// Dissemination-barrier per-round cost intra-node (µs).
    pub barrier_round_us: f64,
    /// Leader's cost to post the spinning status flag (§4.5) (µs).
    pub spin_release_us: f64,
    /// A child's cost to observe the flag after release (µs).
    pub spin_poll_us: f64,
    /// `MPI_Win_sync` (processor memory barrier) (µs).
    pub win_sync_us: f64,
    /// Sender-side per-message overhead `o_s` (µs).
    pub send_overhead_us: f64,
    /// Receiver-side per-message overhead `o_r` (µs).
    pub recv_overhead_us: f64,
    /// Element-wise reduction arithmetic (µs/B processed).
    pub reduce_us_per_byte: f64,
    /// Human name for reports.
    pub name: &'static str,
}

impl NetModel {
    /// NEC "Vulcan" cluster: InfiniBand + Open MPI 4.0.1 (§5.1).
    pub fn infiniband() -> NetModel {
        NetModel {
            internode: LinkParams {
                alpha_us: 1.6,
                beta_us_per_byte: 1.0 / 6800.0, // ~6.8 GB/s effective
                eager_max: 12 * 1024,
                rndv_alpha_us: 1.1,
            },
            nic_lanes: 2, // dual-rail IB — one rail per port
            alpha_shm_us: 0.30,
            beta_mem_us_per_byte: 1.0 / 8000.0, // ~8 GB/s single-copy stream
            shm_copies: 2.0,
            barrier_round_us: 0.35,
            spin_release_us: 0.05,
            spin_poll_us: 0.09,
            win_sync_us: 0.02,
            send_overhead_us: 0.20,
            recv_overhead_us: 0.20,
            reduce_us_per_byte: 1.0 / 4000.0, // ~4 GB/s fused load-op-store
            name: "infiniband (Vulcan, Open MPI 4.0.1)",
        }
    }

    /// Cray XC40 "Hazel Hen": Aries dragonfly + cray-mpich (§5.1).
    pub fn aries() -> NetModel {
        NetModel {
            internode: LinkParams {
                alpha_us: 0.9,
                beta_us_per_byte: 1.0 / 9600.0, // ~9.6 GB/s effective
                eager_max: 8 * 1024,
                rndv_alpha_us: 0.6,
            },
            nic_lanes: 2, // Aries: two injection channels per node model
            alpha_shm_us: 0.25,
            beta_mem_us_per_byte: 1.0 / 10000.0, // Haswell DDR4 stream
            shm_copies: 2.0,
            barrier_round_us: 0.30,
            spin_release_us: 0.04,
            spin_poll_us: 0.08,
            win_sync_us: 0.02,
            send_overhead_us: 0.15,
            recv_overhead_us: 0.15,
            reduce_us_per_byte: 1.0 / 5000.0,
            name: "aries (Hazel Hen, cray-mpich)",
        }
    }

    /// Transfer time between two ranks (µs), excluding send/recv overheads
    /// and NIC serialization (analytic helper; the p2p layer decomposes the
    /// inter-node cost into [`NetModel::nic_occupancy`] — charged on the
    /// sending node's NIC, where concurrent senders serialize — plus
    /// [`NetModel::wire_latency`]).
    #[inline]
    pub fn transfer(&self, same_node: bool, bytes: usize) -> f64 {
        if same_node {
            self.alpha_shm_us + self.shm_copies * self.beta_mem_us_per_byte * bytes as f64
        } else {
            self.internode.transfer(bytes)
        }
    }

    /// Time `bytes` occupy one NIC lane of a node (µs). All inter-node
    /// messages a node injects **on the same lane** serialize — the
    /// contention that makes a pure collective (every rank talking
    /// cross-node) lose to a leader-only bridge. Distinct lanes (see
    /// [`NetModel::nic_lanes`]) proceed in parallel, which is what the
    /// multi-leader bridge exploits.
    #[inline]
    pub fn nic_occupancy(&self, bytes: usize) -> f64 {
        self.internode.beta_us_per_byte * bytes as f64
    }

    /// Per-message wire latency (µs): α plus the rendezvous handshake.
    #[inline]
    pub fn wire_latency(&self, bytes: usize) -> f64 {
        let rndv = if bytes > self.internode.eager_max { self.internode.rndv_alpha_us } else { 0.0 };
        self.internode.alpha_us + rndv
    }

    /// Single on-node memory copy (the hybrid load/store path) (µs).
    #[inline]
    pub fn memcpy(&self, bytes: usize) -> f64 {
        self.beta_mem_us_per_byte * bytes as f64
    }

    /// Element-wise reduction over `bytes` of data (µs).
    #[inline]
    pub fn reduce_cost(&self, bytes: usize) -> f64 {
        self.reduce_us_per_byte * bytes as f64
    }

    /// Dissemination barrier over `p` participants (µs); `spans_nodes`
    /// selects the per-round latency tier.
    #[inline]
    pub fn barrier_cost(&self, p: usize, spans_nodes: bool) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let rounds = (p as f64).log2().ceil();
        let per_round = if spans_nodes {
            self.internode.alpha_us + self.send_overhead_us + self.recv_overhead_us
        } else {
            self.barrier_round_us
        };
        rounds * per_round
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intranode_beats_internode_for_small() {
        let m = NetModel::infiniband();
        assert!(m.transfer(true, 8) < m.transfer(false, 8));
    }

    #[test]
    fn hybrid_single_copy_beats_pure_double_copy() {
        let m = NetModel::infiniband();
        // The core claim of the paper's design: one shared copy vs the
        // library's staging double copy + per-message latency.
        for bytes in [8, 800, 64 * 1024, 1 << 20] {
            assert!(m.memcpy(bytes) < m.transfer(true, bytes), "bytes={bytes}");
        }
    }

    #[test]
    fn rendezvous_kicks_in_above_eager() {
        let m = NetModel::infiniband();
        let just_under = m.internode.transfer(m.internode.eager_max);
        let just_over = m.internode.transfer(m.internode.eager_max + 1);
        assert!(just_over - just_under > m.internode.rndv_alpha_us * 0.99);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let m = NetModel::aries();
        let mut prev = 0.0;
        for bytes in [0, 1, 64, 1024, 1 << 16, 1 << 22] {
            let t = m.transfer(false, bytes);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn barrier_cost_grows_logarithmically() {
        let m = NetModel::infiniband();
        let b16 = m.barrier_cost(16, false);
        let b1024 = m.barrier_cost(1024, false);
        assert!((b1024 / b16 - 10.0 / 4.0).abs() < 1e-9);
        assert_eq!(m.barrier_cost(1, false), 0.0);
    }

    #[test]
    fn spin_cheaper_than_barrier_round() {
        // §4.5: the spinning release sync must be lighter than a barrier.
        for m in [NetModel::infiniband(), NetModel::aries()] {
            assert!(m.spin_release_us + m.spin_poll_us < m.barrier_round_us * 2.0);
        }
    }

    #[test]
    fn both_presets_model_multiple_nic_lanes() {
        // The multi-leader bridge needs at least two independent lanes to
        // overlap; occupancy per lane is unchanged by the lane count.
        for m in [NetModel::infiniband(), NetModel::aries()] {
            assert!(m.nic_lanes >= 2, "{}", m.name);
            assert!(m.nic_occupancy(1 << 20) > 0.0);
        }
    }

    #[test]
    fn aries_has_lower_latency_than_ib() {
        // §5.2.1: Hazel Hen overheads were "one magnitude fewer" — its
        // fabric α must be below Vulcan's.
        assert!(NetModel::aries().internode.alpha_us < NetModel::infiniband().internode.alpha_us);
    }
}
