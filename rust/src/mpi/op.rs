//! Reduction operations (`MPI_Op` analogue), applied element-wise over raw
//! byte buffers according to a [`Datatype`].
//!
//! All predefined ops are commutative **and** associative, which §4.4 of
//! the paper relies on: the hybrid allreduce reduces operands in node-local
//! order rather than ascending rank order, which is only valid for
//! commutative+associative ops (floating-point rounding differences are
//! tolerated the same way MPI implementations tolerate them).

use super::datatype::Datatype;

/// Predefined reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    Sum,
    Prod,
    Min,
    Max,
}

macro_rules! apply_typed {
    ($t:ty, $op:expr, $acc:expr, $src:expr) => {{
        let acc: &mut [$t] = crate::util::bytes_mut_view::<$t>($acc);
        let src: &[$t] = crate::util::bytes_view::<$t>($src);
        debug_assert_eq!(acc.len(), src.len());
        match $op {
            ReduceOp::Sum => {
                for (a, s) in acc.iter_mut().zip(src) {
                    *a = *a + *s;
                }
            }
            ReduceOp::Prod => {
                for (a, s) in acc.iter_mut().zip(src) {
                    *a = *a * *s;
                }
            }
            ReduceOp::Min => {
                for (a, s) in acc.iter_mut().zip(src) {
                    if *s < *a {
                        *a = *s;
                    }
                }
            }
            ReduceOp::Max => {
                for (a, s) in acc.iter_mut().zip(src) {
                    if *s > *a {
                        *a = *s;
                    }
                }
            }
        }
    }};
}

impl ReduceOp {
    /// `acc[i] = acc[i] ⊕ operand[i]` element-wise under `dtype`.
    ///
    /// Panics if the buffers differ in length or are not whole elements.
    pub fn apply(&self, dtype: Datatype, acc: &mut [u8], operand: &[u8]) {
        assert_eq!(acc.len(), operand.len(), "reduce buffers must match");
        assert_eq!(acc.len() % dtype.size(), 0, "partial element in reduce buffer");
        match dtype {
            Datatype::U8 => apply_typed!(u8, self, acc, operand),
            Datatype::I32 => apply_typed!(i32, self, acc, operand),
            Datatype::I64 => apply_typed!(i64, self, acc, operand),
            Datatype::F32 => apply_typed!(f32, self, acc, operand),
            Datatype::F64 => apply_typed!(f64, self, acc, operand),
        }
    }

    /// Identity element for the op under `dtype`, as bytes of one element.
    pub fn identity(&self, dtype: Datatype) -> Vec<u8> {
        macro_rules! ident {
            ($t:ty, $zero:expr, $one:expr, $max:expr, $min:expr) => {
                match self {
                    ReduceOp::Sum => ($zero as $t).to_le_bytes().to_vec(),
                    ReduceOp::Prod => ($one as $t).to_le_bytes().to_vec(),
                    ReduceOp::Min => ($max).to_le_bytes().to_vec(),
                    ReduceOp::Max => ($min).to_le_bytes().to_vec(),
                }
            };
        }
        match dtype {
            Datatype::U8 => ident!(u8, 0, 1, u8::MAX, u8::MIN),
            Datatype::I32 => ident!(i32, 0, 1, i32::MAX, i32::MIN),
            Datatype::I64 => ident!(i64, 0, 1, i64::MAX, i64::MIN),
            Datatype::F32 => ident!(f32, 0.0, 1.0, f32::INFINITY, f32::NEG_INFINITY),
            Datatype::F64 => ident!(f64, 0.0, 1.0, f64::INFINITY, f64::NEG_INFINITY),
        }
    }

    pub const fn name(&self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Prod => "prod",
            ReduceOp::Min => "min",
            ReduceOp::Max => "max",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{cast_slice, to_bytes};

    #[test]
    fn sum_f64() {
        let mut acc = to_bytes(&[1.0f64, 2.0, 3.0]).to_vec();
        let operand = to_bytes(&[10.0f64, 20.0, 30.0]).to_vec();
        ReduceOp::Sum.apply(Datatype::F64, &mut acc, &operand);
        assert_eq!(cast_slice::<f64>(&acc), vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn max_i32() {
        let mut acc = to_bytes(&[5i32, -2, 7]).to_vec();
        let operand = to_bytes(&[3i32, 9, 7]).to_vec();
        ReduceOp::Max.apply(Datatype::I32, &mut acc, &operand);
        assert_eq!(cast_slice::<i32>(&acc), vec![5, 9, 7]);
    }

    #[test]
    fn min_u8() {
        let mut acc = vec![200u8, 3, 50];
        ReduceOp::Min.apply(Datatype::U8, &mut acc, &[100, 4, 60]);
        assert_eq!(acc, vec![100, 3, 50]);
    }

    #[test]
    fn prod_f32() {
        let mut acc = to_bytes(&[2.0f32, 3.0]).to_vec();
        ReduceOp::Prod.apply(Datatype::F32, &mut acc, &to_bytes(&[4.0f32, 0.5]).to_vec());
        assert_eq!(cast_slice::<f32>(&acc), vec![8.0, 1.5]);
    }

    #[test]
    fn identities_absorb() {
        for op in [ReduceOp::Sum, ReduceOp::Prod, ReduceOp::Min, ReduceOp::Max] {
            let mut acc = op.identity(Datatype::F64);
            let x = to_bytes(&[42.5f64]).to_vec();
            op.apply(Datatype::F64, &mut acc, &x);
            assert_eq!(cast_slice::<f64>(&acc), vec![42.5], "op {op:?}");
        }
    }

    #[test]
    #[should_panic(expected = "must match")]
    fn mismatched_lengths_panic() {
        let mut acc = vec![0u8; 8];
        ReduceOp::Sum.apply(Datatype::F64, &mut acc, &[0u8; 16]);
    }
}
