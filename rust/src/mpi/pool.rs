//! Pooled payload slabs — the zero-copy data plane.
//!
//! Every rank owns a [`BufPool`]: a set of power-of-two size-class shelves
//! of recycled byte slabs. The two handle types built on it are
//!
//! - [`Payload`] — a ref-counted immutable slab carried by a
//!   [`Msg`](super::msg::Msg). Fan-out senders clone the handle, not the
//!   bytes; when the last reference drops, the slab returns to the pool of
//!   the rank that allocated it (its *home*), so a steady-state
//!   communication pattern reaches a fixed working set and performs no
//!   further heap allocation.
//! - [`PoolBuf`] — a uniquely-owned mutable scratch buffer for collective
//!   internals (receive staging, reduction accumulators, rotation
//!   buffers). Returned to its home pool on drop. Contents start
//!   *undefined* (stale bytes from the previous user): callers must write
//!   before reading, which every call site in `coll/` does by
//!   construction.
//!
//! The pool never zeroes or shrinks; its working set is bounded by the
//! peak number of simultaneously-live slabs per size class, which for the
//! collective algorithms is a small multiple of the round count.
//!
//! **Legacy mode** ([`BufPool::new`] with `disabled = true`, selected by
//! `ClusterSpec::legacy_dataplane`): every take is a fresh allocation and
//! every put is a free — the pre-refactor allocation behaviour, kept so
//! `bench_all` can measure both data planes in one run. Virtual-time
//! charging is identical in both modes; only wall-clock differs.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Smallest slab handed out (bytes). Must be a power of two.
const MIN_CLASS: usize = 64;
/// Number of size classes: `MIN_CLASS << (NUM_CLASSES - 1)` caps slab
/// size at 128 GiB — far beyond any simulated payload.
const NUM_CLASSES: usize = 32;

/// Index of the smallest class that fits `len` bytes.
fn class_of(len: usize) -> usize {
    let c = len.max(MIN_CLASS).next_power_of_two();
    let idx = (c.trailing_zeros() - MIN_CLASS.trailing_zeros()) as usize;
    assert!(idx < NUM_CLASSES, "payload of {len} bytes exceeds the pool's class range");
    idx
}

/// Capacity of class `idx` in bytes.
fn class_bytes(idx: usize) -> usize {
    MIN_CLASS << idx
}

/// A per-rank recycling allocator for payload and scratch slabs.
pub struct BufPool {
    shelves: [Mutex<Vec<Box<[u8]>>>; NUM_CLASSES],
    /// Shelf occupancy, maintained beside each shelf: a take on an empty
    /// shelf (every first-touch of a size class, and every take while the
    /// class's working set is fully in flight) skips the shelf lock
    /// entirely — at engine scale that is thousands of rank threads not
    /// serializing on locks that have nothing to hand out.
    occupancy: [AtomicU64; NUM_CLASSES],
    hits: AtomicU64,
    misses: AtomicU64,
    disabled: bool,
}

impl BufPool {
    /// `disabled = true` bypasses recycling entirely (legacy data plane).
    pub fn new(disabled: bool) -> BufPool {
        BufPool {
            shelves: std::array::from_fn(|_| Mutex::new(Vec::new())),
            occupancy: std::array::from_fn(|_| AtomicU64::new(0)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            disabled,
        }
    }

    /// Takes (recycled or freshly allocated) a slab whose capacity is the
    /// size class of `len`. Contents are undefined on a recycled hit.
    fn take_slab(&self, len: usize) -> Box<[u8]> {
        let idx = class_of(len);
        if !self.disabled && self.occupancy[idx].load(Ordering::Acquire) > 0 {
            if let Some(slab) = self.shelves[idx].lock().unwrap().pop() {
                self.occupancy[idx].fetch_sub(1, Ordering::Release);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return slab;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        vec![0u8; class_bytes(idx)].into_boxed_slice()
    }

    /// Returns a slab to its shelf (drops it in disabled mode). Called
    /// from whichever thread drops the last handle — receivers return
    /// senders' slabs across threads.
    fn put_slab(&self, slab: Box<[u8]>) {
        if self.disabled || slab.is_empty() {
            return;
        }
        debug_assert!(slab.len().is_power_of_two() && slab.len() >= MIN_CLASS);
        let idx = class_of(slab.len());
        self.shelves[idx].lock().unwrap().push(slab);
        // After the push (lock released ⇒ the slab is takeable), so a
        // racing take that sees the bump always finds the shelf stocked
        // or concurrently being restocked — worst case it re-allocates,
        // which is the pre-optimization behaviour, never a lost slab.
        self.occupancy[idx].fetch_add(1, Ordering::Release);
    }

    /// Takes that found a recycled slab.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Takes that had to allocate (pool-miss allocations — the number the
    /// zero-copy steady-state test pins to zero after warm-up).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// A uniquely-owned pooled scratch buffer (`Deref`s to `[u8]` of the
/// requested length). Returns to its home pool on drop.
pub struct PoolBuf {
    slab: Option<Box<[u8]>>,
    len: usize,
    home: Arc<BufPool>,
}

impl PoolBuf {
    pub(crate) fn take(pool: &Arc<BufPool>, len: usize) -> PoolBuf {
        PoolBuf { slab: Some(pool.take_slab(len)), len, home: pool.clone() }
    }

    /// Converts into an immutable [`Payload`] without copying (the slab
    /// keeps its home pool).
    pub fn into_payload(mut self) -> Payload {
        let slab = self.slab.take().expect("slab present until drop");
        Payload(Arc::new(PayloadBuf { slab, len: self.len, home: Some(self.home.clone()) }))
    }
}

impl Deref for PoolBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.slab.as_ref().expect("slab present until drop")[..self.len]
    }
}

impl DerefMut for PoolBuf {
    fn deref_mut(&mut self) -> &mut [u8] {
        let len = self.len;
        &mut self.slab.as_mut().expect("slab present until drop")[..len]
    }
}

impl Drop for PoolBuf {
    fn drop(&mut self) {
        if let Some(slab) = self.slab.take() {
            self.home.put_slab(slab);
        }
    }
}

struct PayloadBuf {
    slab: Box<[u8]>,
    len: usize,
    /// `None` for payloads adopted from caller-owned `Vec`s — those are
    /// freed normally instead of entering the pool.
    home: Option<Arc<BufPool>>,
}

impl Drop for PayloadBuf {
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            home.put_slab(std::mem::take(&mut self.slab));
        }
    }
}

/// A ref-counted immutable message payload (`Deref`s to `[u8]`). Cloning
/// is a reference-count bump — tree broadcasts fan out one slab.
#[derive(Clone)]
pub struct Payload(Arc<PayloadBuf>);

impl Payload {
    /// Copies `data` into a pooled slab of `pool`.
    pub fn copy_from(pool: &Arc<BufPool>, data: &[u8]) -> Payload {
        let mut slab = pool.take_slab(data.len());
        slab[..data.len()].copy_from_slice(data);
        Payload(Arc::new(PayloadBuf { slab, len: data.len(), home: Some(pool.clone()) }))
    }

    /// Adopts a caller-owned vector without copying (not pooled).
    pub fn from_vec(v: Vec<u8>) -> Payload {
        let len = v.len();
        Payload(Arc::new(PayloadBuf { slab: v.into_boxed_slice(), len, home: None }))
    }

    pub fn len(&self) -> usize {
        self.0.len
    }

    pub fn is_empty(&self) -> bool {
        self.0.len == 0
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self[..].to_vec()
    }
}

impl Deref for Payload {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.0.slab[..self.0.len]
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Payload").field("len", &self.0.len).field("pooled", &self.0.home.is_some()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_round_up() {
        assert_eq!(class_bytes(class_of(0)), 64);
        assert_eq!(class_bytes(class_of(64)), 64);
        assert_eq!(class_bytes(class_of(65)), 128);
        assert_eq!(class_bytes(class_of(100_000)), 128 * 1024);
    }

    #[test]
    fn slabs_recycle_and_count() {
        let pool = Arc::new(BufPool::new(false));
        {
            let mut b = PoolBuf::take(&pool, 100);
            b[0] = 7;
            assert_eq!(b.len(), 100);
        }
        assert_eq!(pool.misses(), 1);
        assert_eq!(pool.hits(), 0);
        {
            let b = PoolBuf::take(&pool, 120); // same 128 B class — recycled
            assert_eq!(b.len(), 120);
        }
        assert_eq!(pool.misses(), 1);
        assert_eq!(pool.hits(), 1);
        let _c = PoolBuf::take(&pool, 4096); // different class — fresh
        assert_eq!(pool.misses(), 2);
    }

    #[test]
    fn disabled_pool_never_recycles() {
        let pool = Arc::new(BufPool::new(true));
        drop(PoolBuf::take(&pool, 64));
        drop(PoolBuf::take(&pool, 64));
        assert_eq!(pool.misses(), 2);
        assert_eq!(pool.hits(), 0);
    }

    #[test]
    fn payload_fanout_shares_one_slab() {
        let pool = Arc::new(BufPool::new(false));
        let p = Payload::copy_from(&pool, &[1, 2, 3]);
        let q = p.clone();
        assert_eq!(&p[..], &[1, 2, 3]);
        assert_eq!(&q[..], &[1, 2, 3]);
        assert_eq!(pool.misses(), 1);
        drop(p);
        assert_eq!(pool.hits(), 0); // q still holds the slab
        drop(q);
        let _r = Payload::copy_from(&pool, &[9; 3]); // recycled
        assert_eq!(pool.hits(), 1);
    }

    #[test]
    fn payload_returns_home_from_another_thread() {
        let pool = Arc::new(BufPool::new(false));
        let p = Payload::copy_from(&pool, &[5; 200]);
        std::thread::spawn(move || drop(p)).join().unwrap();
        let _q = PoolBuf::take(&pool, 200);
        assert_eq!(pool.hits(), 1);
    }

    #[test]
    fn into_payload_keeps_the_slab() {
        let pool = Arc::new(BufPool::new(false));
        let mut b = PoolBuf::take(&pool, 32);
        b.copy_from_slice(&[3u8; 32]);
        let p = b.into_payload();
        assert_eq!(&p[..], &[3u8; 32]);
        drop(p);
        assert_eq!(pool.misses(), 1);
        let _again = PoolBuf::take(&pool, 32);
        assert_eq!(pool.hits(), 1);
    }

    #[test]
    fn from_vec_is_not_pooled() {
        let p = Payload::from_vec(vec![1, 2]);
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
        assert_eq!(p.to_vec(), vec![1, 2]);
    }
}
