//! Process-global cluster state shared by all rank threads.

use super::fault::FaultPlan;
use super::msg::Mailbox;
use super::net::NetModel;
use super::pool::BufPool;
use super::sync::SyncGroup;
use super::topo::Topology;
use super::win::SharedWindow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Behavioral knobs of a simulated cluster, gathered in one place so
/// call sites ([`crate::coordinator::spec::ClusterSpec`], tests,
/// benches) stop churning every time a new mode is added. Construct
/// with `Knobs::default()` and set what you need; `ClusterSpec`'s
/// chainable `with_*` builders delegate here.
#[derive(Clone, Debug)]
pub struct Knobs {
    /// Multiplier from measured host CPU time to charged virtual
    /// compute time (maps this host's core to the paper's testbed core).
    pub compute_scale: f64,
    /// Emulate the pre-refactor allocating data plane (see
    /// [`ClusterState::legacy_dataplane`]).
    pub legacy_dataplane: bool,
    /// Emulate the pre-PR3 mutex+condvar message fabric (see
    /// [`ClusterState::legacy_fabric`]).
    pub legacy_fabric: bool,
    /// Override for the bounded-park doorbell timeout in µs
    /// ([`crate::mpi::sync::set_park_bound_us`]); `None` keeps the
    /// default.
    pub park_bound_us: Option<u64>,
    /// Deterministic fault-injection plan (skew, noise, stragglers,
    /// dead ranks); `None` runs clean.
    pub fault: Option<FaultPlan>,
}

impl Default for Knobs {
    fn default() -> Knobs {
        Knobs {
            compute_scale: 1.0,
            legacy_dataplane: false,
            legacy_fabric: false,
            park_bound_us: None,
            fault: None,
        }
    }
}


/// Calibrated one-off management costs (Table 2 of the paper). These are
/// *fits to the published measurements* — the mechanics (who talks to whom)
/// run for real over the out-of-band control plane, while the virtual-time
/// charge follows the measured scaling laws of the MPI library the paper
/// used. See DESIGN.md §2 and the doc comments on each method.
#[derive(Clone, Debug)]
pub struct MgmtCosts {
    /// `MPI_Comm_split`-family cost coefficient: one split over `p` ranks
    /// costs `comm_split_c * p^0.7` µs (fit to Table 2 "Communicator",
    /// which measures split_type + split: 64.8/170.9/413.7/1098.7 µs at
    /// 16/64/256/1024 cores ⇒ two splits of `4.65·p^0.7`).
    pub comm_split_c: f64,
    /// Shared-window allocation: `alloc_base + alloc_amp·(1 − e^{−(n−1)/3})`
    /// µs over `n` nodes (fit to Table 2 "Allocate": 188.3 → 311.8 µs,
    /// saturating — page-table setup overlaps across nodes).
    pub alloc_base_us: f64,
    pub alloc_amp_us: f64,
    /// Rank-translation table build: `transtable_q · p²` µs (fit to Table 2
    /// "Bcast_transtable", which is quadratic: naive absolute↔relative
    /// translation scans the group per world rank).
    pub transtable_q: f64,
    /// Allgather parameter build (`recvcounts`/`displs` over the bridge):
    /// `param_per_node · n` µs, min `param_min` (Table 2 last row).
    pub param_per_node_us: f64,
    pub param_min_us: f64,
}

impl MgmtCosts {
    /// Open MPI 4.0.1 on Vulcan (Table 2 as printed).
    pub fn vulcan() -> MgmtCosts {
        MgmtCosts {
            comm_split_c: 4.65,
            alloc_base_us: 188.3,
            alloc_amp_us: 124.0,
            transtable_q: 1.4e-3,
            param_per_node_us: 0.31,
            param_min_us: 0.3,
        }
    }

    /// cray-mpich on Hazel Hen: §5.2.1 reports Communicator and
    /// Bcast_transtable "one magnitude fewer"; Allocate/param similar.
    pub fn hazelhen() -> MgmtCosts {
        MgmtCosts { comm_split_c: 0.465, transtable_q: 1.4e-4, ..MgmtCosts::vulcan() }
    }

    /// One communicator split over `p` participants (µs).
    pub fn comm_split_us(&self, p: usize) -> f64 {
        self.comm_split_c * (p as f64).powf(0.7)
    }

    /// `Wrapper_MPI_ShmemBridgeComm_create` = split_type + split (µs).
    pub fn comm_create_us(&self, p: usize) -> f64 {
        2.0 * self.comm_split_us(p)
    }

    /// Shared-memory window allocation over `nnodes` (µs).
    pub fn alloc_us(&self, nnodes: usize) -> f64 {
        self.alloc_base_us + self.alloc_amp_us * (1.0 - (-((nnodes as f64) - 1.0) / 3.0).exp())
    }

    /// Broadcast translation tables over `p` world ranks (µs).
    pub fn transtable_us(&self, p: usize) -> f64 {
        self.transtable_q * (p as f64) * (p as f64)
    }

    /// Allgather recvcounts/displs parameter build over `nnodes` (µs).
    pub fn allgather_param_us(&self, nnodes: usize) -> f64 {
        (self.param_per_node_us * nnodes as f64).max(self.param_min_us)
    }
}

/// Aggregate data-plane traffic counters (perf diagnostics).
#[derive(Default)]
pub struct TrafficCounters {
    pub msgs: AtomicU64,
    pub bytes: AtomicU64,
}

impl TrafficCounters {
    pub fn record(&self, bytes: usize) {
        self.msgs.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }
}

/// Per-communicator synchronization slot (DESIGN.md §5c): the barrier
/// group and the shared-window registry of one communicator, sharded out
/// of the old global `Mutex<HashMap>` registries so that
///
/// - rank threads resolve the slot **once** (at plan/communicator
///   creation; `ProcEnv` memoizes the `Arc`) and every subsequent
///   barrier, spin sync or window operation on the hot path touches no
///   global lock and does zero hash lookups under a lock;
/// - window publish/lookup wakeups stay within the communicator — a
///   leader publishing on one node communicator no longer wakes blocked
///   children of every other node (the old single `Condvar` herd).
pub struct CommCore {
    /// Lazily sized at the first barrier on the communicator.
    sync: OnceLock<Arc<SyncGroup>>,
    /// Live shared windows of this communicator, by allocation sequence.
    windows: Mutex<HashMap<u64, Arc<SharedWindow>>>,
    windows_cv: Condvar,
}

impl CommCore {
    fn new() -> CommCore {
        CommCore {
            sync: OnceLock::new(),
            windows: Mutex::new(HashMap::new()),
            windows_cv: Condvar::new(),
        }
    }

    /// The communicator's barrier/clock-agreement group.
    pub fn sync_group(&self, size: usize) -> Arc<SyncGroup> {
        let g = self.sync.get_or_init(|| Arc::new(SyncGroup::new(size)));
        assert_eq!(g.size(), size, "sync group size mismatch (registered {}, asked {size})", g.size());
        g.clone()
    }

    /// Leader publishes a freshly-allocated shared window.
    pub fn publish_window(&self, seq: u64, win: Arc<SharedWindow>) {
        let mut map = self.windows.lock().unwrap();
        let prev = map.insert(seq, win);
        assert!(prev.is_none(), "window seq {seq} double-published");
        self.windows_cv.notify_all();
    }

    /// Children block until the leader publishes window `seq`.
    pub fn lookup_window(&self, seq: u64) -> Arc<SharedWindow> {
        let mut map = self.windows.lock().unwrap();
        loop {
            if let Some(w) = map.get(&seq) {
                return w.clone();
            }
            map = self.windows_cv.wait(map).unwrap();
        }
    }

    /// Bounded window lookup (the fault-mode variant of
    /// [`CommCore::lookup_window`]): `None` on deadline expiry so the
    /// caller can consult the dead registry instead of parking forever
    /// behind a leader that died — or abandoned the allocation for a
    /// recovery epoch — before publishing.
    pub fn lookup_window_deadline(
        &self,
        seq: u64,
        deadline: std::time::Instant,
    ) -> Option<Arc<SharedWindow>> {
        let mut map = self.windows.lock().unwrap();
        loop {
            if let Some(w) = map.get(&seq) {
                return Some(w.clone());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (m, _timeout) = self.windows_cv.wait_timeout(map, deadline - now).unwrap();
            map = m;
        }
    }

    /// Collective window free (leader side): drop the registry entry.
    pub fn retire_window(&self, seq: u64) {
        self.windows.lock().unwrap().remove(&seq);
    }
}

/// Everything the rank threads share.
pub struct ClusterState {
    pub topo: Topology,
    pub net: NetModel,
    pub mgmt: MgmtCosts,
    /// Multiplier from measured host CPU time to charged virtual compute
    /// time (maps this host's core to the paper's testbed core).
    pub compute_scale: f64,
    pub mailboxes: Vec<Mailbox>,
    /// Per-rank payload slab pools (indexed by world rank); see
    /// [`BufPool`]. Slabs return to their home pool on drop, from
    /// whichever thread drops them.
    pub pools: Vec<Arc<BufPool>>,
    /// When true, the pre-refactor allocating data plane is emulated:
    /// pools never recycle and the hybrid collectives materialize window
    /// regions through copies instead of operating in place. Virtual time
    /// is identical in both modes; only wall-clock differs (`bench_all`
    /// measures the gap).
    pub legacy_dataplane: bool,
    /// When true, the pre-PR3 message fabric is emulated: mailboxes run
    /// the mutex+condvar transport and `ProcEnv` re-resolves the
    /// communicator slot through the global registry on every
    /// synchronization operation (one lock + hash per op). This is a
    /// *conservative* baseline, not a bit-exact revival of the old code:
    /// window condvars stay per-communicator and barrier waiters still
    /// park instead of yielding forever, so the emulated old fabric is
    /// somewhat faster than the real pre-PR3 code and measured speedups
    /// are a lower bound. Messages, results and virtual time are
    /// identical in both modes; only wall-clock differs (`bench_all`
    /// measures the gap).
    pub legacy_fabric: bool,
    /// The active fault-injection plan, if any. Per-rank derived state
    /// (skew factor, noise stream, death schedule) lives on `ProcEnv`;
    /// this is the shared source of truth ranks derive it from.
    pub fault: Option<FaultPlan>,
    pub traffic: TrafficCounters,
    /// Dead-rank registry: `dead[r]` is 0 while world rank `r` is alive,
    /// else `1 + vclock_bits` of the moment it died. A rank marks itself
    /// dead cooperatively (at an injection checkpoint); waiters consult
    /// the registry after a bounded park expires to turn an indefinite
    /// hang into a typed [`super::fault::RankFailed`] error.
    dead: Vec<AtomicU64>,
    /// Fast path for the failure check on clean runs: flipped once by the
    /// first death, so `failed_peer` scans cost one relaxed load until
    /// something actually dies.
    any_dead: AtomicBool,
    next_comm_id: AtomicU64,
    /// Per-node, per-lane NIC busy-until (f64 bits), laid out
    /// `node * nic_lanes + lane`: inter-node sends of a node serialize on
    /// the lane they are bound to ([`NetModel::nic_lanes`] lanes per
    /// node); distinct lanes overlap.
    nic_busy: Vec<AtomicU64>,
    /// Registry of record for per-communicator slots. Cold path only:
    /// rank threads resolve a communicator's [`CommCore`] here once and
    /// hold the `Arc` (rank-privately memoized in `ProcEnv`).
    cores: Mutex<HashMap<u64, Arc<CommCore>>>,
}

impl ClusterState {
    pub fn new(topo: Topology, net: NetModel, mgmt: MgmtCosts, compute_scale: f64) -> Arc<ClusterState> {
        Self::with_knobs(topo, net, mgmt, Knobs { compute_scale, ..Knobs::default() })
    }

    /// [`ClusterState::new`] with every behavioral knob made explicit.
    pub fn with_knobs(
        topo: Topology,
        net: NetModel,
        mgmt: MgmtCosts,
        knobs: Knobs,
    ) -> Arc<ClusterState> {
        let Knobs { compute_scale, legacy_dataplane, legacy_fabric, park_bound_us: _, fault } =
            knobs;
        let world = topo.world_size();
        let nnodes = topo.nnodes();
        let nic_cells = nnodes * net.nic_lanes.max(1);
        Arc::new(ClusterState {
            topo,
            net,
            mgmt,
            compute_scale,
            mailboxes: (0..world).map(|_| Mailbox::with_mode(legacy_fabric)).collect(),
            pools: (0..world).map(|_| Arc::new(BufPool::new(legacy_dataplane))).collect(),
            legacy_dataplane,
            legacy_fabric,
            fault,
            traffic: TrafficCounters::default(),
            dead: (0..world).map(|_| AtomicU64::new(0)).collect(),
            any_dead: AtomicBool::new(false),
            next_comm_id: AtomicU64::new(1), // 0 = world
            nic_busy: (0..nic_cells).map(|_| AtomicU64::new(0)).collect(),
            cores: Mutex::new(HashMap::new()),
        })
    }

    /// Record that world rank `rank` died at virtual time `at` (µs).
    /// Idempotent: the first marking wins. Called by the dying rank
    /// itself at an injection checkpoint
    /// ([`crate::mpi::env::ProcEnv::rank_dead`]).
    pub fn mark_dead(&self, rank: usize, at: f64) {
        let enc = 1 + at.max(0.0).to_bits();
        let _ = self.dead[rank].compare_exchange(0, enc, Ordering::AcqRel, Ordering::Acquire);
        self.any_dead.store(true, Ordering::Release);
    }

    /// Is world rank `rank` registered dead?
    pub fn is_dead(&self, rank: usize) -> bool {
        self.any_dead.load(Ordering::Acquire) && self.dead[rank].load(Ordering::Acquire) != 0
    }

    /// Every world rank currently registered dead, ascending.
    pub fn dead_ranks(&self) -> Vec<usize> {
        if !self.any_dead.load(Ordering::Acquire) {
            return Vec::new();
        }
        (0..self.dead.len()).filter(|&r| self.dead[r].load(Ordering::Acquire) != 0).collect()
    }

    /// True once any rank has been registered dead (one relaxed-ish load;
    /// the fast path for failure checks on clean runs).
    pub fn any_dead(&self) -> bool {
        self.any_dead.load(Ordering::Acquire)
    }

    /// Allocate a globally-unique communicator id (root of a split calls
    /// this once per new group and distributes the id in its reply).
    pub fn alloc_comm_id(&self) -> u64 {
        self.next_comm_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Reserve lane `lane` of the sending node's NIC for `bytes` starting
    /// no earlier than `ready`; returns the wire-injection completion
    /// time. Concurrent senders on the same lane of a node serialize here
    /// — the physical effect behind the paper's hybrid advantage (one
    /// bridge message per node vs one per rank). Distinct lanes overlap;
    /// everything binds to lane 0 unless it explicitly rebinds
    /// ([`crate::mpi::env::ProcEnv::set_nic_lane`]), so single-leader and
    /// pure-MPI traffic serializes exactly as under the old one-NIC model.
    pub fn reserve_nic(&self, node: usize, lane: usize, ready: f64, bytes: usize) -> f64 {
        let dur = self.net.nic_occupancy(bytes);
        let lanes = self.net.nic_lanes.max(1);
        let cell = &self.nic_busy[node * lanes + lane % lanes];
        loop {
            let cur = f64::from_bits(cell.load(Ordering::Acquire));
            let done = cur.max(ready) + dur;
            if cell
                .compare_exchange_weak(
                    cur.to_bits(),
                    done.to_bits(),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return done;
            }
        }
    }

    /// Resolve (or create) the per-communicator slot. Cold path: callers
    /// are expected to hold on to the returned `Arc` (`ProcEnv` memoizes
    /// per rank) so the hot path never comes back here.
    pub fn comm_core(&self, comm_id: u64) -> Arc<CommCore> {
        let mut map = self.cores.lock().unwrap();
        map.entry(comm_id).or_insert_with(|| Arc::new(CommCore::new())).clone()
    }

    /// Shared barrier/clock-agreement group for a communicator
    /// (convenience for cold-path callers; hot paths go through a held
    /// [`CommCore`]).
    pub fn sync_group(&self, comm_id: u64, size: usize) -> Arc<SyncGroup> {
        self.comm_core(comm_id).sync_group(size)
    }

    /// Collective window free (leader side): drop the registry entry.
    pub fn retire_window(&self, comm_id: u64, seq: u64) {
        self.comm_core(comm_id).retire_window(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::topo::Topology;

    fn state() -> Arc<ClusterState> {
        ClusterState::new(Topology::uniform(2, 4), NetModel::infiniband(), MgmtCosts::vulcan(), 1.0)
    }

    #[test]
    fn comm_ids_unique_and_nonzero() {
        let s = state();
        let a = s.alloc_comm_id();
        let b = s.alloc_comm_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn sync_group_shared_by_id() {
        let s = state();
        let g1 = s.sync_group(7, 4);
        let g2 = s.sync_group(7, 4);
        assert!(Arc::ptr_eq(&g1, &g2));
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn sync_group_size_must_agree() {
        let s = state();
        s.sync_group(7, 4);
        s.sync_group(7, 5);
    }

    #[test]
    fn mgmt_costs_match_table2_decades() {
        let m = MgmtCosts::vulcan();
        // Table 2 "Communicator" row: 64.8 / 170.9 / 413.7 / 1098.7 µs.
        for (p, paper) in [(16usize, 64.8), (64, 170.9), (256, 413.7), (1024, 1098.7)] {
            let ours = m.comm_create_us(p);
            assert!((ours / paper) > 0.5 && (ours / paper) < 2.0, "p={p}: {ours:.1} vs {paper}");
        }
        // "Allocate" row: 188.3 / 262.5 / 307.1 / 311.8 µs at 1/4/16/64 nodes.
        for (n, paper) in [(1usize, 188.3), (4, 262.5), (16, 307.1), (64, 311.8)] {
            let ours = m.alloc_us(n);
            assert!((ours / paper) > 0.7 && (ours / paper) < 1.4, "n={n}: {ours:.1} vs {paper}");
        }
        // "Bcast_transtable" row: 0.7 / 9.2 / 95.9 / 1462.8 µs.
        for (p, paper) in [(64usize, 9.2), (256, 95.9), (1024, 1462.8)] {
            let ours = m.transtable_us(p);
            assert!((ours / paper) > 0.3 && (ours / paper) < 3.0, "p={p}: {ours:.1} vs {paper}");
        }
        // "Allgather_param" row: 0.3 / 2.9 / 7.1 / 19.9 µs at 1/4/16/64 nodes.
        for (n, paper) in [(1usize, 0.3), (64, 19.9)] {
            let ours = m.allgather_param_us(n);
            assert!((ours / paper) > 0.5 && (ours / paper) < 2.0, "n={n}: {ours:.2} vs {paper}");
        }
    }

    #[test]
    fn hazelhen_is_a_magnitude_cheaper_on_splits() {
        let v = MgmtCosts::vulcan();
        let h = MgmtCosts::hazelhen();
        assert!((v.comm_create_us(256) / h.comm_create_us(256) - 10.0).abs() < 1e-9);
        assert!((v.transtable_us(256) / h.transtable_us(256) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn nic_lanes_serialize_within_and_overlap_across() {
        let s = state();
        let dur = s.net.nic_occupancy(1000);
        // Two reservations on the same lane back-to-back serialize.
        let a = s.reserve_nic(0, 0, 0.0, 1000);
        let b = s.reserve_nic(0, 0, 0.0, 1000);
        assert!((a - dur).abs() < 1e-12);
        assert!((b - 2.0 * dur).abs() < 1e-12);
        // A different lane of the same node is independent.
        let c = s.reserve_nic(0, 1, 0.0, 1000);
        assert!((c - dur).abs() < 1e-12, "lane 1 must not see lane 0's occupancy");
        // Another node is independent too.
        let d = s.reserve_nic(1, 0, 0.0, 1000);
        assert!((d - dur).abs() < 1e-12);
    }

    #[test]
    fn dead_registry_marks_once_and_lists() {
        let s = state();
        assert!(!s.any_dead());
        assert!(s.dead_ranks().is_empty());
        s.mark_dead(3, 120.5);
        s.mark_dead(3, 999.0); // second marking is a no-op
        s.mark_dead(6, 40.0);
        assert!(s.any_dead());
        assert!(s.is_dead(3) && s.is_dead(6));
        assert!(!s.is_dead(0));
        assert_eq!(s.dead_ranks(), vec![3, 6]);
    }

    #[test]
    fn knobs_default_is_clean() {
        let k = Knobs::default();
        assert_eq!(k.compute_scale, 1.0);
        assert!(!k.legacy_dataplane && !k.legacy_fabric);
        assert!(k.park_bound_us.is_none() && k.fault.is_none());
    }

    #[test]
    fn traffic_counter_accumulates() {
        let s = state();
        s.traffic.record(100);
        s.traffic.record(20);
        assert_eq!(s.traffic.msgs.load(Ordering::Relaxed), 2);
        assert_eq!(s.traffic.bytes.load(Ordering::Relaxed), 120);
    }
}
