//! Thread-level synchronization primitives with virtual-time semantics.
//!
//! [`SyncGroup`] is a sense-counting barrier that *also* agrees on the
//! maximum virtual clock of the participants — the engine's mechanism for
//! realising a modelled `MPI_Barrier` (or harness-level clock alignment)
//! without O(p log p) real message traffic on a 1-core host.
//!
//! [`Doorbell`] is the message fabric's wakeup primitive (DESIGN.md §5c):
//! an event counter rung by producers plus an adaptive spin-then-park
//! waiter. Blocked receivers spin briefly (cheap when the producer is one
//! timeslice away), then *yield* (a spinning thread on this 1-core host
//! would steal the very timeslice the producer needs to make progress),
//! then park the thread entirely so hundreds of idle rank threads cost
//! the scheduler nothing. All waits are bounded (`park_timeout`), so a
//! missed wakeup degrades to a few-millisecond stall, never a hang.
//!
//! [`SpinFlag`] is the paper's §4.5 spinning construct: a shared status
//! counter in a shared-memory window, incremented by the *leader* and
//! polled by the *children* with an equality exit condition (the MPI
//! one-byte-polling restriction the paper discusses). Virtual release time
//! rides along in an atomic f64.

use crate::analysis::race;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Process-unique identities for [`SyncGroup`]s and [`SpinFlag`]s so the
/// happens-before race detector ([`crate::analysis::race`]) can key its
/// per-primitive vector clocks. Zero is never issued (it stays free as a
/// "no identity" sentinel for diagnostics).
static NEXT_SYNC_ID: AtomicU64 = AtomicU64::new(1);

/// Spin/yield budgets, auto-tuned once per process from
/// [`std::thread::available_parallelism`] (the PR-3 constants were tuned
/// for the 1-core CI host and left multi-core runners under-spinning):
///
/// - **1 core**: spinning can never observe progress (the producer needs
///   this very timeslice), so the spin phase is minimal and the yield
///   phase long — handing the core over is the only way forward.
/// - **multi-core**: the partner usually runs concurrently, so a longer
///   spin phase converts most waits into sub-microsecond busy-waits with
///   no scheduler round-trip, and the yield phase shrinks (yielding on a
///   lightly-loaded multi-core host mostly spins through the scheduler
///   anyway — better to park properly and be woken).
struct Budgets {
    /// Iterations of `spin_loop` before a waiter starts yielding.
    spin: u32,
    /// Yields before a waiter escalates to parking (SyncGroup/Doorbell)
    /// or micro-sleeps (SpinFlag).
    yield_: u32,
    /// Auto-tuned park bound (µs) — see [`park_bound`].
    park_us: u64,
}

fn budgets() -> &'static Budgets {
    static BUDGETS: OnceLock<Budgets> = OnceLock::new();
    BUDGETS.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores <= 1 {
            // One core: a parked thread is the *only* way the producer
            // runs; long bounds are safe (rings/unparks cut them short)
            // and avoid busy re-check storms across hundreds of waiters.
            Budgets { spin: 32, yield_: 256, park_us: 2_000 }
        } else {
            // Multi-core: the benign park/ring race is re-checked sooner —
            // a missed wakeup stalls one waiter by 500 µs, not 2 ms, and
            // genuinely idle threads still park (no scheduler load).
            Budgets { spin: (32 * cores as u32).min(1024), yield_: 64, park_us: 500 }
        }
    })
}

#[inline]
fn spin_budget() -> u32 {
    budgets().spin
}

#[inline]
fn yield_budget() -> u32 {
    budgets().yield_
}

/// Configured park bound in µs; 0 = use the auto-tuned default from
/// [`budgets`]. Process-global because the parking primitives are shared
/// by every simulated cluster in the process; it only shapes wall-clock
/// wakeup latency, never modeled virtual time or results.
static PARK_BOUND_US: AtomicU64 = AtomicU64::new(0);

/// Override the park bound (µs). `0` restores the auto-tuned default
/// (2 ms on 1-core hosts, 500 µs on multi-core hosts). Plumbed from
/// `ClusterSpec::park_bound_us`
/// ([`ClusterSpec`](crate::coordinator::spec::ClusterSpec)) by the
/// engine at run start.
pub fn set_park_bound_us(us: u64) {
    PARK_BOUND_US.store(us, Ordering::Relaxed);
}

/// Bound on every park: turns any lost-wakeup bug into a bounded stall
/// instead of a hang, and caps the latency cost of a benign race between
/// "producer rings" and "consumer parks". Auto-tuned per host core count,
/// overridable via [`set_park_bound_us`].
pub fn park_bound() -> Duration {
    let us = PARK_BOUND_US.load(Ordering::Relaxed);
    Duration::from_micros(if us > 0 { us } else { budgets().park_us })
}

/// Atomic max for non-negative f64 values stored as bits (non-negative IEEE
/// doubles order identically to their bit patterns).
#[inline]
pub fn atomic_f64_max(cell: &AtomicU64, value: f64) {
    debug_assert!(value >= 0.0);
    let bits = value.to_bits();
    let mut cur = cell.load(Ordering::Relaxed);
    while bits > cur {
        match cell.compare_exchange_weak(cur, bits, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// The message fabric's wakeup doorbell: an event counter rung on every
/// post, plus an adaptive spin→yield→park waiter for the (single) mailbox
/// owner. Replaces the condvar of the legacy mailbox: ringing an idle
/// doorbell is one uncontended `fetch_add` plus one relaxed flag load —
/// no lock, no syscall — and only the rare "receiver actually parked"
/// path touches the waiter mutex.
pub struct Doorbell {
    /// Total rings so far. A waiter snapshots this *before* its final
    /// queue scan; any ring between scan and park changes the count and
    /// aborts the park, so no post can be missed.
    events: AtomicU64,
    /// True while the owner is parked (or about to park). Producers skip
    /// the waiter mutex entirely while this is false — the common case.
    waiting: AtomicBool,
    /// Parked owner's thread handle (slow path only).
    waiter: Mutex<Option<std::thread::Thread>>,
}

impl Default for Doorbell {
    fn default() -> Self {
        Self::new()
    }
}

impl Doorbell {
    pub fn new() -> Doorbell {
        Doorbell {
            events: AtomicU64::new(0),
            waiting: AtomicBool::new(false),
            waiter: Mutex::new(None),
        }
    }

    /// Current event count. Snapshot this *before* scanning the queues it
    /// guards, then pass it to [`Doorbell::wait_change`].
    pub fn epoch(&self) -> u64 {
        self.events.load(Ordering::SeqCst)
    }

    /// Producer side: record an event and wake the owner if parked.
    pub fn ring(&self) {
        self.events.fetch_add(1, Ordering::SeqCst);
        if self.waiting.load(Ordering::SeqCst) {
            if let Some(t) = self.waiter.lock().unwrap().as_ref() {
                t.unpark();
            }
        }
    }

    /// Owner side: block until the event count moves past `epoch` (may
    /// also return spuriously — callers re-scan and loop). Spin → yield →
    /// park, each phase bounded; see the module docs for the 1-core-host
    /// fairness argument.
    pub fn wait_change(&self, epoch: u64) {
        let (spin, yld) = (spin_budget(), yield_budget());
        let mut tries = 0u32;
        while tries < spin + yld {
            if self.events.load(Ordering::SeqCst) != epoch {
                return;
            }
            if tries < spin {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
            tries += 1;
        }
        *self.waiter.lock().unwrap() = Some(std::thread::current());
        self.waiting.store(true, Ordering::SeqCst);
        // Re-check between publishing the waiting flag and parking: a ring
        // in that window either sees the flag (and unparks — the token
        // makes the park return immediately) or happened before the flag
        // store, in which case this load observes the new count.
        if self.events.load(Ordering::SeqCst) == epoch {
            std::thread::park_timeout(park_bound());
        }
        self.waiting.store(false, Ordering::SeqCst);
    }
}

/// An outstanding split-phase arrival at a [`SyncGroup`] (returned by
/// [`SyncGroup::arrive`]): the generation arrived at, plus the release
/// value when arrival itself completed the barrier (last arriver, or a
/// single-member group).
#[derive(Clone, Copy, Debug)]
pub struct BarrierTicket {
    gen: usize,
    immediate: Option<f64>,
}

/// Barrier over a fixed group that returns the max virtual clock of all
/// participants at arrival.
pub struct SyncGroup {
    id: u64,
    size: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    vmax_acc: AtomicU64,
    released: [AtomicU64; 2],
    /// Threads that escalated past spinning/yielding and parked; the
    /// releasing arriver drains and unparks them. Only the slow path
    /// touches this lock — small groups never reach it.
    sleepers: Mutex<Vec<std::thread::Thread>>,
}

impl SyncGroup {
    pub fn new(size: usize) -> SyncGroup {
        assert!(size > 0);
        SyncGroup {
            id: NEXT_SYNC_ID.fetch_add(1, Ordering::Relaxed),
            size,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            vmax_acc: AtomicU64::new(0),
            released: [AtomicU64::new(0), AtomicU64::new(0)],
            sleepers: Mutex::new(Vec::new()),
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Process-unique identity (race-detector vector-clock key; also the
    /// window-slot identity exported to the static verifier).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Arrive with my virtual clock; block until all `size` members arrive;
    /// return the group's max clock.
    ///
    /// Why this is safe across generations: `released[gen & 1]` is only
    /// overwritten when barrier `gen + 2` completes, which requires every
    /// member — including any straggler still reading `released[gen & 1]` —
    /// to have arrived at barrier `gen + 1`, i.e. to have returned from
    /// `gen` first.
    pub fn arrive_and_wait(&self, my_vtime: f64) -> f64 {
        let t = self.arrive(my_vtime);
        self.finish(&t)
    }

    /// The split-phase half-barrier (DESIGN.md §5e): register my arrival
    /// (never blocks) and return a ticket to [`SyncGroup::poll`] /
    /// [`SyncGroup::finish`] later. The last arriver performs the release
    /// exactly as in [`SyncGroup::arrive_and_wait`], so completing the
    /// ticket immediately is bit- and vtime-identical to the blocking
    /// call. One constraint carries over from the blocking barrier: a
    /// member must not arrive again before the generation its ticket
    /// belongs to has released (the hybrid layer gives every split-phase
    /// handle a *private* group, so handle traffic can never interleave
    /// with user barriers on the communicator's shared group).
    pub fn arrive(&self, my_vtime: f64) -> BarrierTicket {
        if self.size == 1 {
            return BarrierTicket { gen: 0, immediate: Some(my_vtime) };
        }
        let gen = self.generation.load(Ordering::Acquire);
        // Publish my vector clock *before* registering the arrival: the
        // last arriver joins the accumulated clock on this same call, so
        // every publish must already be in place when the count trips.
        race::on_barrier_arrive(self.id, gen);
        atomic_f64_max(&self.vmax_acc, my_vtime);
        if self.count.fetch_add(1, Ordering::AcqRel) == self.size - 1 {
            // Last arriver releases the group. Its ticket is `immediate`
            // and short-circuits poll/finish, so the happens-before join
            // for it must happen here, not there.
            race::on_barrier_finish(self.id, gen);
            let v = self.vmax_acc.swap(0, Ordering::AcqRel);
            self.released[gen & 1].store(v, Ordering::Release);
            self.count.store(0, Ordering::Release);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
            // Wake everyone who parked. Draining after the generation
            // store means a waiter that registers later re-checks the
            // generation (the mutex orders its read after our store) and
            // returns without parking.
            for t in self.sleepers.lock().unwrap().drain(..) {
                t.unpark();
            }
            BarrierTicket { gen, immediate: Some(f64::from_bits(v)) }
        } else {
            BarrierTicket { gen, immediate: None }
        }
    }

    /// Non-blocking completion probe for an [`SyncGroup::arrive`] ticket:
    /// `Some(vmax)` once every member has arrived, `None` otherwise.
    pub fn poll(&self, t: &BarrierTicket) -> Option<f64> {
        if let Some(v) = t.immediate {
            return Some(v);
        }
        if self.generation.load(Ordering::Acquire) != t.gen {
            race::on_barrier_finish(self.id, t.gen);
            Some(f64::from_bits(self.released[t.gen & 1].load(Ordering::Acquire)))
        } else {
            None
        }
    }

    /// Blocking completion of an [`SyncGroup::arrive`] ticket (the second
    /// half of [`SyncGroup::arrive_and_wait`]): spin → yield → park until
    /// the generation releases, then return the group's max clock.
    pub fn finish(&self, t: &BarrierTicket) -> f64 {
        if let Some(v) = t.immediate {
            return v;
        }
        let gen = t.gen;
        {
            let (spin, yld) = (spin_budget(), yield_budget());
            let mut tries = 0u32;
            let mut registered = false;
            while self.generation.load(Ordering::Acquire) == gen {
                tries += 1;
                if tries < spin {
                    std::hint::spin_loop();
                } else if tries < spin + yld {
                    // Single-core host: yield, do not burn the timeslice.
                    std::thread::yield_now();
                } else {
                    // Hundreds of waiters (the 512/1024-rank configs)
                    // must get out of the scheduler entirely: register
                    // once, re-check, park. (In the rare race where the
                    // *previous* generation's releaser is still draining
                    // and swallows this fresh registration, the waiter
                    // degrades to park_bound()-interval polling instead of
                    // re-registering every round — bounded latency beats
                    // an unbounded duplicate pile-up in `sleepers`.)
                    if !registered {
                        self.sleepers.lock().unwrap().push(std::thread::current());
                        registered = true;
                        if self.generation.load(Ordering::Acquire) != gen {
                            break;
                        }
                    }
                    std::thread::park_timeout(park_bound());
                }
            }
            race::on_barrier_finish(self.id, gen);
            f64::from_bits(self.released[gen & 1].load(Ordering::Acquire))
        }
    }

    /// [`SyncGroup::finish`] with a hard wall-clock deadline: spin →
    /// yield → park as usual, but give up and return `None` once
    /// `deadline` passes without the generation releasing. This is the
    /// failure-detection hook — before it, a waiter whose peer died
    /// re-parked forever (only the individual parks were bounded, not
    /// logical progress). On `None` the arrival ticket stays valid: the
    /// caller can consult the dead registry and either surface a
    /// [`crate::mpi::fault::RankFailed`] or re-arm the deadline and keep
    /// waiting. The race-detector join fires only on success.
    pub fn finish_deadline(&self, t: &BarrierTicket, deadline: Instant) -> Option<f64> {
        if let Some(v) = t.immediate {
            return Some(v);
        }
        let gen = t.gen;
        let (spin, yld) = (spin_budget(), yield_budget());
        let mut tries = 0u32;
        let mut registered = false;
        while self.generation.load(Ordering::Acquire) == gen {
            tries += 1;
            if tries < spin {
                std::hint::spin_loop();
            } else if tries < spin + yld {
                std::thread::yield_now();
            } else {
                if Instant::now() >= deadline {
                    return None;
                }
                if !registered {
                    self.sleepers.lock().unwrap().push(std::thread::current());
                    registered = true;
                    if self.generation.load(Ordering::Acquire) != gen {
                        break;
                    }
                }
                std::thread::park_timeout(park_bound());
            }
        }
        race::on_barrier_finish(self.id, gen);
        Some(f64::from_bits(self.released[gen & 1].load(Ordering::Acquire)))
    }
}

/// The paper's spinning status flag (§4.5): leader increments, children
/// poll for equality. Lives inside a shared window in the hybrid layer.
pub struct SpinFlag {
    id: u64,
    status: AtomicU32,
    release_vtime: AtomicU64,
}

impl Default for SpinFlag {
    fn default() -> Self {
        Self::new()
    }
}

impl SpinFlag {
    pub fn new() -> SpinFlag {
        SpinFlag {
            id: NEXT_SYNC_ID.fetch_add(1, Ordering::Relaxed),
            status: AtomicU32::new(0),
            release_vtime: AtomicU64::new(0),
        }
    }

    /// Process-unique identity (race-detector vector-clock key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Leader: publish `status++` with its virtual release time.
    /// (`MPI_Win_sync` on the leader side is the Release ordering here.)
    pub fn post(&self, vtime: f64) {
        // Release edge for the race detector: join my clock into the
        // flag's clock *before* the status increment a child may already
        // be polling on.
        race::on_flag_post(self.id);
        atomic_f64_max(&self.release_vtime, vtime);
        self.status.fetch_add(1, Ordering::Release);
    }

    /// Child: wait until `status` reaches `target` and return the leader's
    /// virtual release time.
    ///
    /// The paper's protocol polls for *equality* (MPI's one-byte-change
    /// restriction, §4.5), which is valid under its usage pattern where a
    /// red sync alternates with every release. Mechanically we compare
    /// monotonically (≥) so a leader that posts its next epoch before a
    /// descheduled child observes the previous one cannot strand the child
    /// — the *cost model* still charges the paper's polling scheme.
    pub fn wait_eq(&self, target: u32) -> f64 {
        let (spin, yld) = (spin_budget(), yield_budget());
        let mut tries = 0u32;
        while self.status.load(Ordering::Acquire) < target {
            tries += 1;
            if tries < spin {
                std::hint::spin_loop();
            } else if tries < spin + yld {
                std::thread::yield_now();
            } else {
                // No doorbell here (the flag models a raw shared-memory
                // word, there is nothing for the poster to ring), so a
                // long wait degrades to a bounded micro-sleep instead of
                // a yield storm across hundreds of polling children.
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        race::on_flag_acquire(self.id);
        f64::from_bits(self.release_vtime.load(Ordering::Acquire))
    }

    /// [`SpinFlag::wait_eq`] with a hard wall-clock deadline: `None` once
    /// `deadline` passes without the status reaching `target`. The
    /// failure-detection counterpart of
    /// [`SyncGroup::finish_deadline`] for the yellow-sync path — a child
    /// polling a flag whose posting leader died otherwise micro-sleeps
    /// forever. The race-detector acquire fires only on success; on
    /// `None` the caller may consult the dead registry and re-arm.
    pub fn wait_eq_deadline(&self, target: u32, deadline: Instant) -> Option<f64> {
        let (spin, yld) = (spin_budget(), yield_budget());
        let mut tries = 0u32;
        while self.status.load(Ordering::Acquire) < target {
            tries += 1;
            if tries < spin {
                std::hint::spin_loop();
            } else if tries < spin + yld {
                std::thread::yield_now();
            } else {
                if Instant::now() >= deadline {
                    return None;
                }
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        race::on_flag_acquire(self.id);
        Some(f64::from_bits(self.release_vtime.load(Ordering::Acquire)))
    }

    /// Non-blocking probe of [`SpinFlag::wait_eq`]: `Some(release_vtime)`
    /// once `status` has reached `target`, `None` otherwise. The charge
    /// model is the caller's job (one poll iteration per call that
    /// returns `Some` — the same single `spin_poll_us` the blocking wait
    /// charges).
    pub fn try_wait_eq(&self, target: u32) -> Option<f64> {
        if self.status.load(Ordering::Acquire) >= target {
            race::on_flag_acquire(self.id);
            Some(f64::from_bits(self.release_vtime.load(Ordering::Acquire)))
        } else {
            None
        }
    }

    /// Current status value (diagnostics / tests).
    pub fn status(&self) -> u32 {
        self.status.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn budgets_are_sane_for_this_host() {
        let b = budgets();
        assert!(b.spin >= 32 && b.spin <= 1024);
        assert!(b.yield_ >= 64 || b.spin == 32, "1-core keeps the long yield phase");
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores > 1 {
            assert!(b.spin >= 64, "multi-core hosts spin longer before syscalls");
        }
    }

    #[test]
    fn atomic_max_keeps_largest() {
        let cell = AtomicU64::new(0);
        atomic_f64_max(&cell, 3.5);
        atomic_f64_max(&cell, 1.25);
        atomic_f64_max(&cell, 7.0);
        atomic_f64_max(&cell, 6.9);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 7.0);
    }

    #[test]
    fn single_member_barrier_is_identity() {
        let g = SyncGroup::new(1);
        assert_eq!(g.arrive_and_wait(5.5), 5.5);
    }

    #[test]
    fn barrier_returns_group_max() {
        let g = Arc::new(SyncGroup::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let g = g.clone();
                std::thread::spawn(move || g.arrive_and_wait(i as f64 * 10.0))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 30.0);
        }
    }

    #[test]
    fn barrier_reusable_across_generations() {
        let g = Arc::new(SyncGroup::new(3));
        for round in 0..50 {
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    let g = g.clone();
                    std::thread::spawn(move || g.arrive_and_wait((round * 3 + i) as f64))
                })
                .collect();
            let expected = (round * 3 + 2) as f64;
            for h in handles {
                assert_eq!(h.join().unwrap(), expected, "round {round}");
            }
        }
    }

    #[test]
    fn doorbell_wakes_parked_waiter() {
        let bell = Arc::new(Doorbell::new());
        let b2 = bell.clone();
        let epoch = bell.epoch();
        let h = std::thread::spawn(move || {
            // Loop like a real consumer: wait_change may return spuriously.
            while b2.epoch() == epoch {
                b2.wait_change(epoch);
            }
            b2.epoch()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        bell.ring();
        assert_eq!(h.join().unwrap(), epoch + 1);
    }

    #[test]
    fn doorbell_ring_before_wait_returns_immediately() {
        let bell = Doorbell::new();
        let epoch = bell.epoch();
        bell.ring();
        // Already-changed epoch: must not block at all.
        bell.wait_change(epoch);
        assert_eq!(bell.epoch(), epoch + 1);
    }

    #[test]
    fn doorbell_counts_every_ring() {
        let bell = Arc::new(Doorbell::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let b = bell.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        b.ring();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(bell.epoch(), 400);
    }

    #[test]
    fn barrier_wakes_parked_stragglers() {
        // Force the park path: one waiter arrives long before the rest.
        let g = Arc::new(SyncGroup::new(2));
        let g2 = g.clone();
        let h = std::thread::spawn(move || g2.arrive_and_wait(1.0));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(g.arrive_and_wait(2.0), 2.0);
        assert_eq!(h.join().unwrap(), 2.0);
    }

    #[test]
    fn split_arrival_polls_false_then_completes() {
        let g = Arc::new(SyncGroup::new(3));
        let t0 = g.arrive(10.0);
        assert!(g.poll(&t0).is_none(), "two members missing");
        let t1 = g.arrive(30.0);
        assert!(g.poll(&t1).is_none(), "one member missing");
        let t2 = g.arrive(20.0);
        // The last arriver released the group: every ticket resolves to
        // the same vmax, and polling is idempotent.
        for t in [&t0, &t1, &t2] {
            assert_eq!(g.poll(t), Some(30.0));
            assert_eq!(g.poll(t), Some(30.0));
            assert_eq!(g.finish(t), 30.0);
        }
    }

    #[test]
    fn split_arrival_matches_blocking_barrier() {
        // arrive + finish must agree with arrive_and_wait across threads
        // and generations.
        let g = Arc::new(SyncGroup::new(3));
        for round in 0..20 {
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    let g = g.clone();
                    std::thread::spawn(move || {
                        let v = (round * 3 + i) as f64;
                        if i == 0 {
                            g.arrive_and_wait(v)
                        } else {
                            let t = g.arrive(v);
                            g.finish(&t)
                        }
                    })
                })
                .collect();
            let expected = (round * 3 + 2) as f64;
            for h in handles {
                assert_eq!(h.join().unwrap(), expected, "round {round}");
            }
        }
    }

    #[test]
    fn single_member_split_arrival_is_immediate() {
        let g = SyncGroup::new(1);
        let t = g.arrive(7.5);
        assert_eq!(g.poll(&t), Some(7.5));
        assert_eq!(g.finish(&t), 7.5);
    }

    #[test]
    fn spin_flag_try_wait() {
        let f = SpinFlag::new();
        assert!(f.try_wait_eq(1).is_none());
        f.post(42.0);
        assert_eq!(f.try_wait_eq(1), Some(42.0));
        assert_eq!(f.try_wait_eq(1), Some(42.0), "probe is idempotent");
        assert!(f.try_wait_eq(2).is_none());
    }

    #[test]
    fn park_bound_auto_default_is_sane() {
        // Only the auto default is asserted here: the override is a
        // process-global that every `SimCluster::run` (re)applies, so
        // asserting a specific override value would race with
        // concurrently running cluster tests. The spec→engine plumbing
        // is covered by `coordinator::spec` instead.
        let auto = Duration::from_micros(budgets().park_us);
        assert!(auto >= Duration::from_micros(500) && auto <= Duration::from_millis(2));
        assert!(park_bound() >= Duration::from_micros(1), "bound must be non-trivial");
    }

    #[test]
    fn finish_deadline_times_out_then_succeeds_after_release() {
        let g = Arc::new(SyncGroup::new(2));
        let t = g.arrive(1.0);
        // Nobody else arrives: the bounded wait must give up, not hang.
        let start = Instant::now();
        assert!(g.finish_deadline(&t, start + Duration::from_millis(20)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(20));
        // Ticket stays valid: once the peer arrives, re-arming succeeds.
        let g2 = g.clone();
        let h = std::thread::spawn(move || g2.arrive_and_wait(9.0));
        let v = loop {
            if let Some(v) = g.finish_deadline(&t, Instant::now() + Duration::from_millis(50)) {
                break v;
            }
        };
        assert_eq!(v, 9.0);
        assert_eq!(h.join().unwrap(), 9.0);
    }

    #[test]
    fn finish_deadline_immediate_ticket_ignores_deadline() {
        let g = SyncGroup::new(1);
        let t = g.arrive(3.25);
        // Already-released (single-member) tickets complete even with a
        // deadline in the past.
        assert_eq!(g.finish_deadline(&t, Instant::now() - Duration::from_secs(1)), Some(3.25));
    }

    #[test]
    fn wait_eq_deadline_times_out_then_sees_post() {
        let f = Arc::new(SpinFlag::new());
        let start = Instant::now();
        assert!(f.wait_eq_deadline(1, start + Duration::from_millis(20)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(20));
        f.post(77.0);
        assert_eq!(f.wait_eq_deadline(1, Instant::now() + Duration::from_secs(1)), Some(77.0));
    }

    #[test]
    fn spin_flag_release_order() {
        let f = Arc::new(SpinFlag::new());
        let f2 = f.clone();
        let child = std::thread::spawn(move || f2.wait_eq(1));
        std::thread::sleep(std::time::Duration::from_millis(10));
        f.post(123.0);
        assert_eq!(child.join().unwrap(), 123.0);
        assert_eq!(f.status(), 1);
    }

    #[test]
    fn spin_flag_multiple_epochs() {
        let f = Arc::new(SpinFlag::new());
        for epoch in 1..=5u32 {
            let f2 = f.clone();
            let child = std::thread::spawn(move || f2.wait_eq(epoch));
            f.post(epoch as f64);
            let v = child.join().unwrap();
            assert!(v >= epoch as f64);
        }
    }
}
