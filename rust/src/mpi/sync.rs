//! Thread-level synchronization primitives with virtual-time semantics.
//!
//! [`SyncGroup`] is a sense-counting barrier that *also* agrees on the
//! maximum virtual clock of the participants — the engine's mechanism for
//! realising a modelled `MPI_Barrier` (or harness-level clock alignment)
//! without O(p log p) real message traffic on a 1-core host.
//!
//! [`SpinFlag`] is the paper's §4.5 spinning construct: a shared status
//! counter in a shared-memory window, incremented by the *leader* and
//! polled by the *children* with an equality exit condition (the MPI
//! one-byte-polling restriction the paper discusses). Virtual release time
//! rides along in an atomic f64.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

/// Atomic max for non-negative f64 values stored as bits (non-negative IEEE
/// doubles order identically to their bit patterns).
#[inline]
pub fn atomic_f64_max(cell: &AtomicU64, value: f64) {
    debug_assert!(value >= 0.0);
    let bits = value.to_bits();
    let mut cur = cell.load(Ordering::Relaxed);
    while bits > cur {
        match cell.compare_exchange_weak(cur, bits, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Barrier over a fixed group that returns the max virtual clock of all
/// participants at arrival.
pub struct SyncGroup {
    size: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    vmax_acc: AtomicU64,
    released: [AtomicU64; 2],
}

impl SyncGroup {
    pub fn new(size: usize) -> SyncGroup {
        assert!(size > 0);
        SyncGroup {
            size,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            vmax_acc: AtomicU64::new(0),
            released: [AtomicU64::new(0), AtomicU64::new(0)],
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Arrive with my virtual clock; block until all `size` members arrive;
    /// return the group's max clock.
    ///
    /// Why this is safe across generations: `released[gen & 1]` is only
    /// overwritten when barrier `gen + 2` completes, which requires every
    /// member — including any straggler still reading `released[gen & 1]` —
    /// to have arrived at barrier `gen + 1`, i.e. to have returned from
    /// `gen` first.
    pub fn arrive_and_wait(&self, my_vtime: f64) -> f64 {
        if self.size == 1 {
            return my_vtime;
        }
        let gen = self.generation.load(Ordering::Acquire);
        atomic_f64_max(&self.vmax_acc, my_vtime);
        if self.count.fetch_add(1, Ordering::AcqRel) == self.size - 1 {
            // Last arriver releases the group.
            let v = self.vmax_acc.swap(0, Ordering::AcqRel);
            self.released[gen & 1].store(v, Ordering::Release);
            self.count.store(0, Ordering::Release);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
            f64::from_bits(v)
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                spins += 1;
                if spins < 32 {
                    std::hint::spin_loop();
                } else {
                    // Single-core host: yield, do not burn the timeslice.
                    std::thread::yield_now();
                }
            }
            f64::from_bits(self.released[gen & 1].load(Ordering::Acquire))
        }
    }
}

/// The paper's spinning status flag (§4.5): leader increments, children
/// poll for equality. Lives inside a shared window in the hybrid layer.
pub struct SpinFlag {
    status: AtomicU32,
    release_vtime: AtomicU64,
}

impl Default for SpinFlag {
    fn default() -> Self {
        Self::new()
    }
}

impl SpinFlag {
    pub fn new() -> SpinFlag {
        SpinFlag { status: AtomicU32::new(0), release_vtime: AtomicU64::new(0) }
    }

    /// Leader: publish `status++` with its virtual release time.
    /// (`MPI_Win_sync` on the leader side is the Release ordering here.)
    pub fn post(&self, vtime: f64) {
        atomic_f64_max(&self.release_vtime, vtime);
        self.status.fetch_add(1, Ordering::Release);
    }

    /// Child: wait until `status` reaches `target` and return the leader's
    /// virtual release time.
    ///
    /// The paper's protocol polls for *equality* (MPI's one-byte-change
    /// restriction, §4.5), which is valid under its usage pattern where a
    /// red sync alternates with every release. Mechanically we compare
    /// monotonically (≥) so a leader that posts its next epoch before a
    /// descheduled child observes the previous one cannot strand the child
    /// — the *cost model* still charges the paper's polling scheme.
    pub fn wait_eq(&self, target: u32) -> f64 {
        let mut spins = 0u32;
        while self.status.load(Ordering::Acquire) < target {
            spins += 1;
            if spins < 32 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        f64::from_bits(self.release_vtime.load(Ordering::Acquire))
    }

    /// Current status value (diagnostics / tests).
    pub fn status(&self) -> u32 {
        self.status.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn atomic_max_keeps_largest() {
        let cell = AtomicU64::new(0);
        atomic_f64_max(&cell, 3.5);
        atomic_f64_max(&cell, 1.25);
        atomic_f64_max(&cell, 7.0);
        atomic_f64_max(&cell, 6.9);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 7.0);
    }

    #[test]
    fn single_member_barrier_is_identity() {
        let g = SyncGroup::new(1);
        assert_eq!(g.arrive_and_wait(5.5), 5.5);
    }

    #[test]
    fn barrier_returns_group_max() {
        let g = Arc::new(SyncGroup::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let g = g.clone();
                std::thread::spawn(move || g.arrive_and_wait(i as f64 * 10.0))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 30.0);
        }
    }

    #[test]
    fn barrier_reusable_across_generations() {
        let g = Arc::new(SyncGroup::new(3));
        for round in 0..50 {
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    let g = g.clone();
                    std::thread::spawn(move || g.arrive_and_wait((round * 3 + i) as f64))
                })
                .collect();
            let expected = (round * 3 + 2) as f64;
            for h in handles {
                assert_eq!(h.join().unwrap(), expected, "round {round}");
            }
        }
    }

    #[test]
    fn spin_flag_release_order() {
        let f = Arc::new(SpinFlag::new());
        let f2 = f.clone();
        let child = std::thread::spawn(move || f2.wait_eq(1));
        std::thread::sleep(std::time::Duration::from_millis(10));
        f.post(123.0);
        assert_eq!(child.join().unwrap(), 123.0);
        assert_eq!(f.status(), 1);
    }

    #[test]
    fn spin_flag_multiple_epochs() {
        let f = Arc::new(SpinFlag::new());
        for epoch in 1..=5u32 {
            let f2 = f.clone();
            let child = std::thread::spawn(move || f2.wait_eq(epoch));
            f.post(epoch as f64);
            let v = child.join().unwrap();
            assert!(v >= epoch as f64);
        }
    }
}
