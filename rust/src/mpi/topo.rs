//! Cluster topology: which world rank lives on which shared-memory node.
//!
//! The paper's experiments depend on the *rank placement scheme*: the
//! default **block-style** placement (consecutive ranks fill a node before
//! moving on — §4, §5) and the alternative round-robin placement (§4.4's
//! commutativity discussion). Irregular populations (Hazel Hen's 24-core
//! nodes under power-of-two rank requests — §5.2.2) are first-class.

/// Rank placement scheme (`--map-by` in Open MPI terms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Consecutive ranks fill each node before moving to the next
    /// (the paper's default; `--map-by core`).
    Block,
    /// Ranks are dealt across nodes like cards (`--map-by node`).
    RoundRobin,
}

/// Immutable map rank ⇄ (node, slot).
#[derive(Clone, Debug)]
pub struct Topology {
    /// Ranks hosted per node, in world-rank terms: `nodes[n]` lists the
    /// world ranks on node `n` in local-rank order.
    nodes: Vec<Vec<usize>>,
    /// Node index per world rank.
    node_of: Vec<usize>,
    /// Local slot (index into `nodes[node_of[r]]`) per world rank.
    slot_of: Vec<usize>,
    placement: Placement,
}

impl Topology {
    /// Build a topology for `counts[n]` ranks on node `n` under `placement`.
    ///
    /// Panics if any node count is zero (an empty node would make the
    /// leader election in the hybrid layer meaningless).
    pub fn new(counts: &[usize], placement: Placement) -> Topology {
        assert!(!counts.is_empty(), "cluster must have at least one node");
        assert!(counts.iter().all(|&c| c > 0), "every node must host at least one rank");
        let world = counts.iter().sum::<usize>();
        let mut nodes: Vec<Vec<usize>> = counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        match placement {
            Placement::Block => {
                let mut r = 0usize;
                for (n, &c) in counts.iter().enumerate() {
                    for _ in 0..c {
                        nodes[n].push(r);
                        r += 1;
                    }
                }
            }
            Placement::RoundRobin => {
                // Deal rank r to the next node that still has a free slot.
                let mut remaining: Vec<usize> = counts.to_vec();
                let mut n = 0usize;
                for r in 0..world {
                    while remaining[n % counts.len()] == 0 {
                        n += 1;
                    }
                    let node = n % counts.len();
                    nodes[node].push(r);
                    remaining[node] -= 1;
                    n += 1;
                }
            }
        }
        let mut node_of = vec![0usize; world];
        let mut slot_of = vec![0usize; world];
        for (n, ranks) in nodes.iter().enumerate() {
            for (s, &r) in ranks.iter().enumerate() {
                node_of[r] = n;
                slot_of[r] = s;
            }
        }
        Topology { nodes, node_of, slot_of, placement }
    }

    /// Uniform cluster: `nnodes` nodes × `per_node` ranks, block placement.
    pub fn uniform(nnodes: usize, per_node: usize) -> Topology {
        Topology::new(&vec![per_node; nnodes], Placement::Block)
    }

    pub fn world_size(&self) -> usize {
        self.node_of.len()
    }

    pub fn nnodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of[rank]
    }

    /// Local slot of `rank` on its node (0 = the node leader under the
    /// paper's lowest-rank-leads convention *for block placement*; in
    /// general the leader is the lowest world rank on the node).
    pub fn slot_of(&self, rank: usize) -> usize {
        self.slot_of[rank]
    }

    /// World ranks on node `n`, in slot order.
    pub fn ranks_on(&self, n: usize) -> &[usize] {
        &self.nodes[n]
    }

    /// The node leader = lowest world rank hosted on node `n`.
    pub fn leader_of_node(&self, n: usize) -> usize {
        *self.nodes[n].iter().min().expect("nodes are non-empty")
    }

    /// Whether two ranks share a node (load/store domain).
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_placement_fills_nodes_in_order() {
        let t = Topology::new(&[3, 2], Placement::Block);
        assert_eq!(t.ranks_on(0), &[0, 1, 2]);
        assert_eq!(t.ranks_on(1), &[3, 4]);
        assert_eq!(t.node_of(2), 0);
        assert_eq!(t.node_of(3), 1);
        assert_eq!(t.slot_of(4), 1);
        assert_eq!(t.leader_of_node(1), 3);
    }

    #[test]
    fn round_robin_deals_ranks() {
        let t = Topology::new(&[2, 2], Placement::RoundRobin);
        assert_eq!(t.ranks_on(0), &[0, 2]);
        assert_eq!(t.ranks_on(1), &[1, 3]);
        assert_eq!(t.leader_of_node(0), 0);
        assert_eq!(t.leader_of_node(1), 1);
    }

    #[test]
    fn round_robin_irregular_counts() {
        // 3 + 1 ranks: node 1 fills up after one deal, remainder to node 0.
        let t = Topology::new(&[3, 1], Placement::RoundRobin);
        assert_eq!(t.world_size(), 4);
        assert_eq!(t.ranks_on(1).len(), 1);
        assert_eq!(t.ranks_on(0).len(), 3);
        // Every rank appears exactly once.
        let mut all: Vec<usize> = (0..2).flat_map(|n| t.ranks_on(n).to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
    }

    #[test]
    fn uniform_helper() {
        let t = Topology::uniform(4, 24);
        assert_eq!(t.world_size(), 96);
        assert_eq!(t.nnodes(), 4);
        assert!(t.same_node(0, 23));
        assert!(!t.same_node(23, 24));
    }

    #[test]
    #[should_panic(expected = "at least one rank")]
    fn zero_count_node_rejected() {
        Topology::new(&[4, 0], Placement::Block);
    }

    #[test]
    fn slots_are_consistent_inverse() {
        for placement in [Placement::Block, Placement::RoundRobin] {
            let t = Topology::new(&[5, 3, 7], placement);
            for r in 0..t.world_size() {
                let n = t.node_of(r);
                let s = t.slot_of(r);
                assert_eq!(t.ranks_on(n)[s], r);
            }
        }
    }
}
