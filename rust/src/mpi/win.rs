//! MPI-3 shared-memory windows (`MPI_Win_allocate_shared` analogue).
//!
//! A [`SharedWindow`] is one contiguous region allocated by the node
//! *leader* and mapped by all on-node ranks — the storage substrate of the
//! paper's hybrid collectives. Each on-node rank contributes a segment
//! size; `segment(local_rank)` plays the role of `MPI_Win_shared_query`
//! (base pointer + size of another rank's contribution).
//!
//! ## Safety discipline
//!
//! Rank threads read/write the window concurrently through raw pointers,
//! exactly like real MPI SHM programs do through `mmap`ed memory. Safety is
//! protocol-level, not type-level: the hybrid collectives guarantee
//! (a) writers touch only their affinity segment between two sync points,
//! (b) readers only read after an Acquire sync (barrier or spin flag) that
//! happens-after the writers' Release. This mirrors the paper's §4.5
//! discussion of `MPI_Win_sync` and data integrity.

use crate::analysis::race;

use super::sync::{SpinFlag, SyncGroup};
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Process-unique window identities for the analysis subsystem
/// (DESIGN.md §6): race reports and schedule models name windows by id.
static NEXT_WIN_ID: AtomicU64 = AtomicU64::new(1);

/// Number of spin flags carried by every window: the hybrid protocols use
/// flag 0 for the leader→children release and flag 1 for auxiliary phases.
pub const WIN_FLAGS: usize = 4;

/// A node-shared memory region with per-rank affinity segments.
///
/// Storage is `u64`-backed so the base address is always 8-byte aligned:
/// kernels view gathered f64 payloads in place (`from_bytes::<f64>`), and
/// a `Vec<u8>` allocation would only be aligned by allocator accident.
///
/// The cells are per-*word* `UnsafeCell`s and every view is derived from
/// the raw base pointer ([`SharedWindow::base`]) rather than a
/// whole-buffer `&`/`&mut` temporary, so simultaneous live views of
/// disjoint ranges (e.g. a reduction reading input slots while writing
/// slot `L` in place) are sound under Rust's aliasing model, not merely
/// correct in practice.
pub struct SharedWindow {
    /// Process-unique identity (analysis subsystem key).
    id: u64,
    buf: Box<[UnsafeCell<u64>]>,
    total: usize,
    /// Byte offset of each local rank's segment.
    offsets: Vec<usize>,
    /// Byte size of each local rank's segment.
    sizes: Vec<usize>,
    /// Status flags for the §4.5 spinning synchronization.
    flags: [SpinFlag; WIN_FLAGS],
    /// Window-private barrier groups (DESIGN.md §5e): slot 0 is the
    /// node-level red/yellow sync of the split-phase schedules, slot 1 the
    /// leader-set sync. Private so an in-flight split-phase handle's
    /// arrivals can never interleave with user barriers (or other
    /// handles) on the communicator's shared [`SyncGroup`].
    syncs: [OnceLock<Arc<SyncGroup>>; 2],
}

// Safety: see module docs — concurrent access is governed by the
// collective protocols' Release/Acquire sync points.
unsafe impl Send for SharedWindow {}
unsafe impl Sync for SharedWindow {}

impl SharedWindow {
    /// Allocate a window from per-local-rank contribution sizes (bytes).
    /// Contiguous layout (the MPI default: `alloc_shared_noncontig` false).
    pub fn allocate(sizes: &[usize]) -> SharedWindow {
        let total: usize = sizes.iter().sum();
        let mut offsets = Vec::with_capacity(sizes.len());
        let mut acc = 0usize;
        for &s in sizes {
            offsets.push(acc);
            acc += s;
        }
        SharedWindow {
            id: NEXT_WIN_ID.fetch_add(1, Ordering::Relaxed),
            buf: (0..total.div_ceil(8)).map(|_| UnsafeCell::new(0u64)).collect(),
            total,
            offsets,
            sizes: sizes.to_vec(),
            flags: Default::default(),
            syncs: [OnceLock::new(), OnceLock::new()],
        }
    }

    /// Process-unique window identity — the key the analysis subsystem
    /// (race reports, exported schedule models) names this window by.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Window-private barrier group `slot` over `size` participants,
    /// lazily created on first use (same contract as
    /// [`CommCore::sync_group`](super::state::CommCore::sync_group): every
    /// participant must ask with the same size).
    pub fn sync_group(&self, slot: usize, size: usize) -> Arc<SyncGroup> {
        let g = self.syncs[slot].get_or_init(|| Arc::new(SyncGroup::new(size)));
        assert_eq!(g.size(), size, "window sync slot {slot} size mismatch");
        g.clone()
    }

    /// Raw base pointer of the region. Derived from the shared slice
    /// reference (legal to write through thanks to the `UnsafeCell`
    /// elements); never materializes a whole-buffer `&mut`.
    fn base(&self) -> *mut u8 {
        self.buf.as_ptr() as *mut u8
    }

    /// Total window size in bytes.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of contributing local ranks.
    pub fn nsegments(&self) -> usize {
        self.offsets.len()
    }

    /// `MPI_Win_shared_query`: (offset, size) of local rank `r`'s segment.
    pub fn segment(&self, r: usize) -> (usize, usize) {
        (self.offsets[r], self.sizes[r])
    }

    /// Overflow-proof bounds check (an `offset + len` sum can wrap; the
    /// subtractive form cannot).
    #[inline]
    fn check(&self, offset: usize, len: usize, what: &str) {
        assert!(offset <= self.total && len <= self.total - offset, "window {what} out of bounds");
    }

    /// Raw read view. Caller must hold an Acquire sync ordering after the
    /// writers' Release (see module docs).
    ///
    /// # Safety
    /// No concurrent writer may overlap `[offset, offset+len)`.
    pub unsafe fn slice(&self, offset: usize, len: usize) -> &[u8] {
        self.check(offset, len, "view");
        race::on_access(self.id, offset, len, false);
        std::slice::from_raw_parts(self.base().add(offset) as *const u8, len)
    }

    /// Raw write view.
    ///
    /// # Safety
    /// The protocol must guarantee exclusive access to
    /// `[offset, offset+len)` until the next sync point; other live views
    /// (from [`SharedWindow::slice`]/`slice_mut`) must not overlap it.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [u8] {
        self.check(offset, len, "view");
        race::on_access(self.id, offset, len, true);
        std::slice::from_raw_parts_mut(self.base().add(offset), len)
    }

    /// Copy `data` into the window at `offset` (real copy; the caller
    /// charges `net.memcpy` to its virtual clock).
    ///
    /// Panics on out-of-bounds.
    pub fn write(&self, offset: usize, data: &[u8]) {
        self.check(offset, data.len(), "write");
        race::on_access(self.id, offset, data.len(), true);
        unsafe {
            std::slice::from_raw_parts_mut(self.base().add(offset), data.len())
                .copy_from_slice(data);
        }
    }

    /// Copy `out.len()` bytes from the window at `offset` into `out`.
    pub fn read_into(&self, offset: usize, out: &mut [u8]) {
        self.check(offset, out.len(), "read");
        race::on_access(self.id, offset, out.len(), false);
        unsafe {
            out.copy_from_slice(std::slice::from_raw_parts(
                self.base().add(offset) as *const u8,
                out.len(),
            ));
        }
    }

    /// Copy out a fresh vector (convenience for tests/examples).
    pub fn read_vec(&self, offset: usize, len: usize) -> Vec<u8> {
        let mut v = vec![0u8; len];
        self.read_into(offset, &mut v);
        v
    }

    /// Copy `len` bytes from `src` to `dst` inside the window — the
    /// in-place slot-to-slot move of the hybrid reductions, replacing a
    /// `read_vec` + `write` round-trip. The caller charges `net.memcpy`
    /// and must hold protocol-exclusive access to both ranges. Every
    /// protocol use moves between *disjoint* slots; debug builds assert
    /// it (overlapping moves would also be modeled wrong by the charge
    /// law, which prices one clean memcpy).
    pub fn copy_within(&self, src: usize, dst: usize, len: usize) {
        self.check(src, len, "copy");
        self.check(dst, len, "copy");
        debug_assert!(
            src == dst || src.abs_diff(dst) >= len,
            "window copy_within ranges overlap: src {src}, dst {dst}, len {len}"
        );
        race::on_access(self.id, src, len, false);
        race::on_access(self.id, dst, len, true);
        unsafe {
            let base = self.base();
            std::ptr::copy(base.add(src), base.add(dst), len);
        }
    }

    /// Status flag `i` (the §4.5 spinning protocol).
    pub fn flag(&self, i: usize) -> &SpinFlag {
        &self.flags[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn layout_is_contiguous_in_rank_order() {
        let w = SharedWindow::allocate(&[16, 8, 0, 24]);
        assert_eq!(w.len(), 48);
        assert_eq!(w.nsegments(), 4);
        assert_eq!(w.segment(0), (0, 16));
        assert_eq!(w.segment(1), (16, 8));
        assert_eq!(w.segment(2), (24, 0));
        assert_eq!(w.segment(3), (24, 24));
    }

    #[test]
    fn write_then_read_roundtrip() {
        let w = SharedWindow::allocate(&[8, 8]);
        w.write(8, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(w.read_vec(8, 8), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        // Untouched segment stays zeroed.
        assert_eq!(w.read_vec(0, 8), vec![0; 8]);
    }

    #[test]
    fn copy_within_moves_slots() {
        let w = SharedWindow::allocate(&[8, 8, 8]);
        w.write(0, &[5; 8]);
        w.copy_within(0, 16, 8);
        assert_eq!(w.read_vec(16, 8), vec![5; 8]);
        assert_eq!(w.read_vec(8, 8), vec![0; 8]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn copy_within_bounds_checked() {
        let w = SharedWindow::allocate(&[8]);
        w.copy_within(4, 0, 8);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_write_panics() {
        let w = SharedWindow::allocate(&[4]);
        w.write(2, &[0; 4]);
    }

    #[test]
    fn leader_allocates_all_children_zero() {
        // The paper's allocation pattern: msize*nprocs on the leader,
        // zero bytes contributed by children.
        let w = SharedWindow::allocate(&[100, 0, 0, 0]);
        assert_eq!(w.len(), 100);
        assert_eq!(w.segment(3), (100, 0));
    }

    #[test]
    fn window_ids_are_process_unique() {
        let a = SharedWindow::allocate(&[8]);
        let b = SharedWindow::allocate(&[8]);
        assert_ne!(a.id(), b.id());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn wrapping_offset_is_caught() {
        // `offset + len` would wrap around usize::MAX and pass a naive
        // additive check; the subtractive form must reject it.
        let w = SharedWindow::allocate(&[8]);
        w.read_into(usize::MAX - 2, &mut [0u8; 8]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlap")]
    fn copy_within_overlap_is_debug_checked() {
        let w = SharedWindow::allocate(&[16]);
        w.copy_within(0, 4, 8);
    }

    #[test]
    fn copy_within_identity_and_disjoint_allowed() {
        let w = SharedWindow::allocate(&[16]);
        w.write(0, &[3; 4]);
        w.copy_within(0, 0, 4); // src == dst is a no-op, not an overlap
        w.copy_within(0, 4, 4);
        assert_eq!(w.read_vec(4, 4), vec![3; 4]);
    }

    #[test]
    fn cross_thread_visibility_via_flag() {
        let w = Arc::new(SharedWindow::allocate(&[8]));
        let w2 = w.clone();
        let child = std::thread::spawn(move || {
            w2.flag(0).wait_eq(1);
            w2.read_vec(0, 8)
        });
        w.write(0, &[9; 8]);
        w.flag(0).post(1.0); // Release: write happens-before child's read
        assert_eq!(child.join().unwrap(), vec![9; 8]);
    }
}
