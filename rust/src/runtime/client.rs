//! The PJRT client wrapper: artifact discovery, lazy compilation cache,
//! and typed f64 execution. Compiled only under the `pjrt` cargo feature
//! (needs the vendored `xla` + `anyhow` crates); default builds use
//! [`stub`](super::stub) instead.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serializes protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see aot.py and DESIGN.md).
//!
//! The `xla` crate's handles hold `Rc`s, so [`Runtime`] is single-threaded
//! by construction; rank threads share it through [`SharedRuntime`], which
//! serializes *every* operation behind one mutex — sound because no `Rc`
//! is ever touched outside the lock, and free on this host because the
//! PJRT CPU client would serialize on the single core anyway.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

/// One typed input: data + dims (row-major). Empty dims = scalar.
pub struct F64Input<'a> {
    pub data: &'a [f64],
    pub dims: &'a [i64],
}

impl<'a> F64Input<'a> {
    pub fn new(data: &'a [f64], dims: &'a [i64]) -> F64Input<'a> {
        let n: i64 = dims.iter().product();
        assert_eq!(data.len() as i64, if dims.is_empty() { 1 } else { n });
        F64Input { data, dims }
    }
}

/// The artifact runtime: one PJRT CPU client + compiled-executable cache.
/// Not `Send`/`Sync` — see [`SharedRuntime`].
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open a runtime over an artifacts directory.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = dir.into();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, dir, cache: HashMap::new() })
    }

    /// Discover the artifacts directory: `$HYMPI_ARTIFACTS`, then
    /// `./artifacts`, then `../artifacts`.
    pub fn discover() -> Result<Runtime> {
        if let Ok(d) = std::env::var("HYMPI_ARTIFACTS") {
            return Runtime::new(d);
        }
        for cand in ["artifacts", "../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Runtime::new(cand);
            }
        }
        Err(anyhow!(
            "no artifacts directory found (run `make artifacts` or set HYMPI_ARTIFACTS)"
        ))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Does artifact `name` exist on disk?
    pub fn available(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on f64 inputs; returns each tuple output
    /// flattened row-major. (All artifacts lower with `return_tuple=True`.)
    pub fn exec_f64(&mut self, name: &str, inputs: &[F64Input]) -> Result<Vec<Vec<f64>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| -> Result<xla::Literal> {
                if i.dims.is_empty() {
                    Ok(xla::Literal::scalar(i.data[0]))
                } else {
                    xla::Literal::vec1(i.data)
                        .reshape(i.dims)
                        .map_err(|e| anyhow!("reshape to {:?}: {e}", i.dims))
                }
            })
            .collect::<Result<_>>()?;
        let exe = self.exe(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f64>().map_err(|e| anyhow!("read f64 output of {name}: {e}")))
            .collect()
    }
}

/// Thread-shared runtime: one global mutex around the whole [`Runtime`].
///
/// # Safety
/// `Runtime`'s non-`Send` parts (`Rc` handles inside the `xla` crate) are
/// only ever created, cloned and dropped while the mutex is held, and no
/// reference to them escapes `with`. Moving the *locked container* across
/// threads is therefore sound.
pub struct SharedRuntime {
    inner: Mutex<Runtime>,
}

unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl SharedRuntime {
    /// Process-wide shared runtime (`None` if artifacts are absent — the
    /// kernels then fall back to their native compute paths).
    pub fn global() -> Option<&'static SharedRuntime> {
        static RT: OnceLock<Option<SharedRuntime>> = OnceLock::new();
        RT.get_or_init(|| Runtime::discover().ok().map(|rt| SharedRuntime { inner: Mutex::new(rt) }))
            .as_ref()
    }

    pub fn available(&self, name: &str) -> bool {
        self.inner.lock().unwrap().available(name)
    }

    pub fn exec_f64(&self, name: &str, inputs: &[F64Input]) -> Result<Vec<Vec<f64>>> {
        self.inner.lock().unwrap().exec_f64(name, inputs)
    }

    /// Run an arbitrary closure against the locked runtime.
    pub fn with<R>(&self, f: impl FnOnce(&mut Runtime) -> R) -> R {
        f(&mut self.inner.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Option<&'static SharedRuntime> {
        let rt = SharedRuntime::global();
        if rt.is_none() {
            eprintln!("skipping PJRT test: no artifacts (run `make artifacts`)");
        }
        rt
    }

    #[test]
    fn summa64_matches_native_matmul() {
        let Some(rt) = rt() else { return };
        let n = 64usize;
        let a: Vec<f64> = (0..n * n).map(|i| (i % 13) as f64 * 0.25).collect();
        let b: Vec<f64> = (0..n * n).map(|i| (i % 7) as f64 - 3.0).collect();
        let c: Vec<f64> = (0..n * n).map(|i| (i % 5) as f64).collect();
        let dims = [n as i64, n as i64];
        let out = rt
            .exec_f64(
                "summa64",
                &[F64Input::new(&a, &dims), F64Input::new(&b, &dims), F64Input::new(&c, &dims)],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let mut want = c.clone();
        crate::kernels::native::matmul_acc(&a, &b, &mut want, n, n, n);
        for (g, w) in out[0].iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn poisson_artifact_matches_native_sweep() {
        let Some(rt) = rt() else { return };
        let (rows, n) = (8usize, 64usize);
        let strip: Vec<f64> = (0..(rows + 2) * n).map(|i| ((i * 37) % 11) as f64 * 0.1).collect();
        let out = rt
            .exec_f64("poisson_r8_n64", &[F64Input::new(&strip, &[(rows + 2) as i64, n as i64])])
            .unwrap();
        assert_eq!(out.len(), 2);
        let mut want = strip.clone();
        let want_delta = crate::kernels::native::rb_sweep(&mut want, rows + 2, n);
        for (g, w) in out[0].iter().zip(&want) {
            assert!((g - w).abs() < 1e-12, "{g} vs {w}");
        }
        assert!((out[1][0] - want_delta).abs() < 1e-12);
    }

    #[test]
    fn bpmf_artifact_matches_native_posterior() {
        let Some(rt) = rt() else { return };
        let (batch, nnz, k) = (32usize, 16usize, 10usize);
        let v: Vec<f64> = (0..batch * nnz * k).map(|i| ((i * 29 + 7) % 13) as f64 * 0.1 - 0.6).collect();
        let w: Vec<f64> = (0..batch * nnz).map(|i| ((i * 17 + 3) % 7) as f64 * 0.5 - 1.0).collect();
        let lam0 = vec![1.5f64; k];
        let noise: Vec<f64> = (0..batch * k).map(|i| ((i % 5) as f64 - 2.0) * 0.3).collect();
        let out = rt
            .exec_f64(
                "bpmf_b32_n16_k10",
                &[
                    F64Input::new(&v, &[batch as i64, nnz as i64, k as i64]),
                    F64Input::new(&w, &[batch as i64, nnz as i64]),
                    F64Input::new(&[2.0], &[]),
                    F64Input::new(&lam0, &[k as i64]),
                    F64Input::new(&noise, &[batch as i64, k as i64]),
                ],
            )
            .unwrap();
        let mut want = vec![0.0; batch * k];
        crate::kernels::native::bpmf_posterior(&v, &w, 2.0, &lam0, &noise, batch, nnz, k, &mut want);
        for (g, wv) in out[0].iter().zip(&want) {
            assert!((g - wv).abs() < 1e-8, "{g} vs {wv}");
        }
    }

    #[test]
    fn missing_artifact_reports_cleanly() {
        let Some(rt) = rt() else { return };
        assert!(!rt.available("nonesuch"));
        assert!(rt.exec_f64("nonesuch", &[]).is_err());
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = rt() else { return };
        let n = 64usize;
        let a = vec![0.0f64; n * n];
        let dims = [n as i64, n as i64];
        let t0 = std::time::Instant::now();
        rt.exec_f64("summa64", &[F64Input::new(&a, &dims), F64Input::new(&a, &dims), F64Input::new(&a, &dims)])
            .unwrap();
        let first = t0.elapsed();
        let t1 = std::time::Instant::now();
        rt.exec_f64("summa64", &[F64Input::new(&a, &dims), F64Input::new(&a, &dims), F64Input::new(&a, &dims)])
            .unwrap();
        let second = t1.elapsed();
        assert!(second < first, "second call {second:?} should reuse the cache (first {first:?})");
    }
}
