//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! Python never runs at request time: `make artifacts` is the only step
//! that touches jax, and the resulting `artifacts/*.hlo.txt` are compiled
//! here once per process via the PJRT CPU client (`xla` crate).

pub mod client;

pub use client::{F64Input, Runtime, SharedRuntime};
