//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! Python never runs at request time: `make artifacts` is the only step
//! that touches jax, and the resulting `artifacts/*.hlo.txt` are compiled
//! here once per process via the PJRT CPU client (`xla` crate).
//!
//! The real client is gated behind the off-by-default **`pjrt`** cargo
//! feature (it needs the vendored `xla` + `anyhow` crates). The default
//! build compiles [`stub`] instead: the same API surface, with
//! [`SharedRuntime::global`] reporting "no artifacts" so every kernel
//! falls through to the bit-equivalent native backend
//! ([`crate::kernels::native`]). This keeps the default dependency graph
//! empty — `cargo build` works with no registry access at all.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub use client::{F64Input, Runtime, SharedRuntime};

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{F64Input, Runtime, SharedRuntime};
