//! Stub runtime for builds without the `pjrt` feature.
//!
//! Mirrors the API surface of [`client`](super::client) so call sites
//! compile unchanged: [`SharedRuntime::global`] always returns `None`
//! ("no artifacts discovered"), which routes every
//! [`Backend::Pjrt`](crate::kernels::Backend::Pjrt) dispatch to the
//! native fallback and makes [`Backend::auto`](crate::kernels::Backend::auto)
//! resolve to [`Backend::Native`](crate::kernels::Backend::Native).

use crate::util::Error;

/// One typed input: data + dims (row-major). Empty dims = scalar.
pub struct F64Input<'a> {
    pub data: &'a [f64],
    pub dims: &'a [i64],
}

impl<'a> F64Input<'a> {
    pub fn new(data: &'a [f64], dims: &'a [i64]) -> F64Input<'a> {
        let n: i64 = dims.iter().product();
        assert_eq!(data.len() as i64, if dims.is_empty() { 1 } else { n });
        F64Input { data, dims }
    }
}

/// Placeholder for the artifact runtime; never constructible without the
/// `pjrt` feature.
pub struct Runtime {
    _priv: (),
}

impl Runtime {
    /// Discovery always fails in the stub: there is no PJRT client.
    pub fn discover() -> crate::Result<Runtime> {
        Err(Error::msg("built without the `pjrt` feature: no PJRT runtime available"))
    }
}

/// Thread-shared runtime stub: reports artifact absence everywhere.
pub struct SharedRuntime {
    _priv: (),
}

impl SharedRuntime {
    /// Always `None` — artifacts cannot be executed without `pjrt`.
    pub fn global() -> Option<&'static SharedRuntime> {
        None
    }

    pub fn available(&self, _name: &str) -> bool {
        false
    }

    pub fn exec_f64(&self, name: &str, _inputs: &[F64Input<'_>]) -> crate::Result<Vec<Vec<f64>>> {
        Err(Error::msg(format!(
            "cannot execute artifact '{name}': built without the `pjrt` feature"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_is_absent() {
        assert!(SharedRuntime::global().is_none());
    }

    #[test]
    fn discover_reports_feature_gate() {
        let e = Runtime::discover().err().unwrap();
        assert!(e.to_string().contains("pjrt"));
    }

    #[test]
    fn backend_auto_falls_back_to_native() {
        assert_eq!(crate::kernels::Backend::auto(), crate::kernels::Backend::Native);
    }
}
