//! The algorithm-selection subsystem (UCC-style): one layer through
//! which **every** algorithm choice in the library flows.
//!
//! Production collective stacks (UCC — see SNIPPETS.md 1–2) converged on
//! the same shape this module implements: algorithm implementations are
//! *components*, a *selection layer* scores the viable candidates for a
//! given `(op, message size, communicator size, topology)` point, and
//! "repetitive collective operations (init once and invoke multiple
//! times)" amortize the cost of choosing well. Our persistent-collective
//! engine ([`crate::coll::PlanCache`]) already is the repetitive-
//! collective model; this module supplies the selection layer:
//!
//! - [`Selector`] — the one trait behind which every decision lives.
//!   The pure-MPI `Auto` dispatches ([`crate::coll::bcast`],
//!   [`crate::coll::allgather`], [`crate::coll::allreduce`] — and through
//!   them the hierarchical collectives of [`crate::coll::hier`] and the
//!   hybrid layer's internal bridge collectives), plan-time resolution in
//!   [`crate::coll::PlanCache`], and the §5.2.4 step-1 method resolution
//!   in `HybridCtx::*_init` all consult a `Selector` instead of
//!   hard-coded tables.
//! - [`StaticSelector`] — the fallback provider: the Open MPI 4.0.1
//!   decision tables of [`crate::coll::Tuning`] (overridable per-run via
//!   `Tuning::from_env` / CLI flags) behind the trait.
//! - [`registry`](self::registry) — the candidate-plan registry: every
//!   *viable* `(algorithm, segment size)` for a point, each with a
//!   closed-form α-β cost estimate derived from [`crate::mpi::NetModel`]
//!   ([`ModelSelector`] picks the arg-min).
//! - [`table`](self::table) — the versioned persisted tuning table
//!   (`TUNING.json`, committed like the arXiv 2007.06892 per-cluster
//!   tables; [`TableSelector`] consults it before any fallback).
//! - [`tuner`](self::tuner) — the online [`Autotuner`]: consult the
//!   table, else cost-model the registry; plus the race helper that
//!   `PlanCache::plan_raced`, `bin/tune_all` and `bench_all --tuned` use
//!   to time candidates empirically on persistent handles.
//!
//! ## The process-wide selector
//!
//! The `Auto` dispatch sites sit deep inside free functions with no
//! session object to hang state on, so the installed selector is
//! process-wide: [`global`] reads it, [`install`] swaps it (returning
//! the previous one). The default is the static tables wrapped behind
//! the committed `TUNING.json` (if present and non-empty) — i.e. the
//! table is *loaded once, consulted before any re-tuning*, exactly the
//! UCC persisted-tuning shape. Binaries (`bench_all --tuned`,
//! `tune_all`, `verify_schedules`) install richer selectors; library
//! tests never install, so `cargo test` always sees the static default.

pub mod registry;
pub mod table;
pub mod tuner;

use crate::coll::allgather::AllgatherAlgo;
use crate::coll::allreduce::AllreduceAlgo;
use crate::coll::bcast::BcastAlgo;
use crate::coll::tuning::Tuning;
use crate::hybrid::allreduce::AllreduceMethod;
use std::sync::{Arc, OnceLock, RwLock};

pub use registry::{ModelSelector, SelectPoint};
pub use table::{TableSelector, TuningTable, TABLE_VERSION};
pub use tuner::{race, Autotuner, PinnedSelector, RaceOutcome, TuneMode};

/// One layer, every choice: the decisions a production MPI's tuned
/// module makes, as a trait.
///
/// Contract: implementations return a *bound, viable* choice — never
/// `Auto`/`Tuned`, never `RecursiveDoubling` allgather on a
/// non-power-of-two communicator (callers sanitize defensively, see
/// [`sanitize_allgather`]).
pub trait Selector: Send + Sync {
    /// Human-readable name for reports (`"static"`, `"model"`, …).
    fn describe(&self) -> String;

    /// Broadcast algorithm for a `p`-rank communicator, `bytes` payload.
    fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo;

    /// Allgather algorithm (`bytes` = per-rank contribution).
    fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo;

    /// Allreduce algorithm (`bytes` = operand size).
    fn allreduce_algo(&self, p: usize, bytes: usize) -> AllreduceAlgo;

    /// §5.2.4 step-1 method for the hybrid allreduce family (`bytes` =
    /// the size the bridge moves per node).
    fn allreduce_method(&self, bytes: usize) -> AllreduceMethod;
}

/// The static-fallback provider: the hard-coded Open MPI 4.0.1 decision
/// tables ([`Tuning`]) behind the [`Selector`] trait.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticSelector {
    tuning: Tuning,
}

impl StaticSelector {
    pub fn new(tuning: Tuning) -> StaticSelector {
        StaticSelector { tuning }
    }

    /// The decision tables this selector serves.
    pub fn tuning(&self) -> &Tuning {
        &self.tuning
    }
}

impl Selector for StaticSelector {
    fn describe(&self) -> String {
        "static (Open MPI 4.0.1 tables)".to_string()
    }

    fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        self.tuning.bcast_algo(p, bytes)
    }

    fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        self.tuning.allgather_algo(p, bytes)
    }

    fn allreduce_algo(&self, p: usize, bytes: usize) -> AllreduceAlgo {
        self.tuning.allreduce_algo(p, bytes)
    }

    fn allreduce_method(&self, bytes: usize) -> AllreduceMethod {
        self.tuning.allreduce_method(bytes)
    }
}

/// Defensive viability clamp for allgather choices: recursive doubling
/// asserts a power-of-two communicator, so a selector (or a stale table
/// entry) naming it for any other `p` degrades to ring instead of
/// aborting the run.
pub fn sanitize_allgather(algo: AllgatherAlgo, p: usize) -> AllgatherAlgo {
    match algo {
        AllgatherAlgo::RecursiveDoubling if !p.is_power_of_two() => AllgatherAlgo::Ring,
        a => a,
    }
}

static GLOBAL: OnceLock<RwLock<Arc<dyn Selector>>> = OnceLock::new();

fn cell() -> &'static RwLock<Arc<dyn Selector>> {
    GLOBAL.get_or_init(|| RwLock::new(default_selector()))
}

/// The default process-wide selector: the static tables (with any
/// `HYMPI_*` env overrides applied once), wrapped behind the committed
/// tuning table when one is present and non-empty — so persisted
/// winners are consulted before the static fallback, without any
/// behavioral change while `TUNING.json` is the empty schema
/// placeholder. `HYMPI_TUNING=off` skips the table entirely.
fn default_selector() -> Arc<dyn Selector> {
    let stat: Arc<dyn Selector> = Arc::new(StaticSelector::new(Tuning::from_env()));
    if std::env::var("HYMPI_TUNING").map(|v| v == "off").unwrap_or(false) {
        return stat;
    }
    match TuningTable::load(&table::default_path()) {
        Ok(t) if !t.entries.is_empty() => Arc::new(TableSelector::new(t, stat)),
        _ => stat,
    }
}

/// The installed process-wide selector (a cheap `Arc` clone).
pub fn global() -> Arc<dyn Selector> {
    cell().read().expect("selector lock").clone()
}

/// Install a process-wide selector, returning the previous one (so a
/// driver can restore it). Binaries only; library code and tests should
/// thread selectors explicitly (`PlanCache::with_selector`).
pub fn install(s: Arc<dyn Selector>) -> Arc<dyn Selector> {
    std::mem::replace(&mut *cell().write().expect("selector lock"), s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_selector_matches_the_tables() {
        let s = StaticSelector::default();
        let t = Tuning::default();
        for (p, b) in [(2, 100), (8, 4096), (32, 500_000), (64, 9 * 1024)] {
            assert_eq!(s.bcast_algo(p, b), t.bcast_algo(p, b));
            assert_eq!(s.allgather_algo(p, b), t.allgather_algo(p, b));
            assert_eq!(s.allreduce_algo(p, b), t.allreduce_algo(p, b));
        }
        assert_eq!(s.allreduce_method(2048), AllreduceMethod::Method2);
        assert_eq!(s.allreduce_method(2049), AllreduceMethod::Method1);
    }

    #[test]
    fn sanitize_degrades_rd_on_non_pow2() {
        assert_eq!(sanitize_allgather(AllgatherAlgo::RecursiveDoubling, 8), AllgatherAlgo::RecursiveDoubling);
        assert_eq!(sanitize_allgather(AllgatherAlgo::RecursiveDoubling, 12), AllgatherAlgo::Ring);
        assert_eq!(sanitize_allgather(AllgatherAlgo::Bruck, 12), AllgatherAlgo::Bruck);
    }

    #[test]
    fn global_default_is_static_or_table_backed() {
        // Never a panic, and always a bound decision (not Auto/Tuned).
        let g = global();
        assert!(!matches!(g.bcast_algo(8, 4096), BcastAlgo::Auto));
        assert!(!matches!(g.allreduce_method(100), AllreduceMethod::Tuned));
    }
}
