//! The candidate-plan registry: every viable `(algorithm, segment
//! size)` for a selection point, each with a closed-form α-β cost
//! estimate derived from the [`NetModel`].
//!
//! The estimates are *rankings*, not predictions: they reuse the same
//! link laws the simulator charges (`LinkParams::transfer`, the
//! shared-memory double copy, the reduction throughput) but collapse
//! per-hop topology to a mixed intra/inter-node average, so absolute
//! values are coarse while the crossovers land where the paper's tuned
//! tables put them. When exactness matters, the race path
//! ([`super::tuner::race`] / `PlanCache::plan_raced`) times candidates
//! on the live engine instead and the model is only the tie-breaker
//! seed.

use crate::coll::allgather::AllgatherAlgo;
use crate::coll::allreduce::AllreduceAlgo;
use crate::coll::bcast::BcastAlgo;
use crate::coll::tuning::Tuning;
use crate::hybrid::allreduce::AllreduceMethod;
use crate::mpi::net::NetModel;

use super::Selector;

/// One selection point: the coordinates every decision keys on.
#[derive(Clone, Copy, Debug)]
pub struct SelectPoint {
    /// Communicator size.
    pub p: usize,
    /// The op's natural message size in bytes (per-rank block for
    /// allgather, payload for bcast, operand for allreduce).
    pub bytes: usize,
    /// Ranks per node (topology hint; 1 = every rank on its own node).
    pub ranks_per_node: usize,
}

impl SelectPoint {
    pub fn new(p: usize, bytes: usize, ranks_per_node: usize) -> SelectPoint {
        SelectPoint { p, bytes, ranks_per_node: ranks_per_node.max(1) }
    }
}

/// A scored candidate.
#[derive(Clone, Copy, Debug)]
pub struct Candidate<A> {
    pub algo: A,
    /// Closed-form cost estimate (µs).
    pub cost_us: f64,
}

/// Average cost of one tree/ring hop of `bytes` at this point: hops of
/// a flat algorithm under block placement are intra-node with
/// probability `(rpn − 1)/(p − 1)`-ish; we use the byte-weighted mix of
/// the two link laws. Single-node communicators are purely intra-node.
fn hop_us(net: &NetModel, pt: SelectPoint, bytes: usize) -> f64 {
    let intra = net.transfer(true, bytes);
    if pt.p <= pt.ranks_per_node {
        return intra;
    }
    let inter = net.transfer(false, bytes) + net.send_overhead_us + net.recv_overhead_us;
    let frac_intra = (pt.ranks_per_node.saturating_sub(1)) as f64 / (pt.p - 1) as f64;
    frac_intra * intra + (1.0 - frac_intra) * inter
}

fn log2_ceil(p: usize) -> usize {
    (usize::BITS - p.saturating_sub(1).leading_zeros()) as usize
}

fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

// ---------------------------------------------------------------------
// Closed-form per-algorithm estimates.
// ---------------------------------------------------------------------

/// Binomial tree: `⌈log2 p⌉` serial full-message hops on the critical
/// path.
pub fn cost_bcast_binomial(net: &NetModel, pt: SelectPoint) -> f64 {
    log2_ceil(pt.p) as f64 * hop_us(net, pt, pt.bytes)
}

/// Split-binary tree: each half pipelines `seg`-sized chunks down a
/// depth-`⌈log2 p⌉−1` binary subtree (each parent forwards every
/// segment to *two* children serially, hence the factor 2 per slot),
/// then subtree pairs exchange halves.
pub fn cost_bcast_split_binary(net: &NetModel, pt: SelectPoint, seg: usize) -> f64 {
    let half = (pt.bytes / 2).max(1);
    let seg = seg.min(half).max(1);
    let slots = log2_ceil(pt.p).saturating_sub(1) + div_ceil(half, seg) - 1;
    slots as f64 * 2.0 * hop_us(net, pt, seg) + hop_us(net, pt, half)
}

/// Segmented chain: `p − 1 + nseg − 1` pipeline slots of one segment.
pub fn cost_bcast_pipeline(net: &NetModel, pt: SelectPoint, seg: usize) -> f64 {
    let seg = seg.min(pt.bytes).max(1);
    let slots = pt.p - 1 + div_ceil(pt.bytes, seg) - 1;
    slots as f64 * hop_us(net, pt, seg)
}

/// Van de Geijn: binomial scatter of halving blocks, then a ring
/// allgather of `bytes/p` blocks.
pub fn cost_bcast_scatter_allgather(net: &NetModel, pt: SelectPoint) -> f64 {
    let mut cost = 0.0;
    let mut blk = pt.bytes;
    for _ in 0..log2_ceil(pt.p) {
        blk = (blk / 2).max(1);
        cost += hop_us(net, pt, blk);
    }
    cost + (pt.p - 1) as f64 * hop_us(net, pt, (pt.bytes / pt.p).max(1))
}

/// Bruck: `⌈log2 p⌉` rounds, round `i` moving `min(2^i, p − 2^i)`
/// blocks.
pub fn cost_allgather_bruck(net: &NetModel, pt: SelectPoint) -> f64 {
    let m = pt.bytes.max(1);
    let mut cost = 0.0;
    let mut sent = 1usize;
    while sent < pt.p {
        cost += hop_us(net, pt, sent.min(pt.p - sent) * m);
        sent *= 2;
    }
    cost
}

/// Recursive doubling (power-of-two only): round `i` exchanges `2^i`
/// blocks.
pub fn cost_allgather_rd(net: &NetModel, pt: SelectPoint) -> f64 {
    let m = pt.bytes.max(1);
    (0..log2_ceil(pt.p)).map(|i| hop_us(net, pt, (1usize << i) * m)).sum()
}

/// Ring: `p − 1` single-block neighbor steps.
pub fn cost_allgather_ring(net: &NetModel, pt: SelectPoint) -> f64 {
    (pt.p - 1) as f64 * hop_us(net, pt, pt.bytes.max(1))
}

/// Recursive doubling allreduce: `⌈log2 p⌉` full-operand exchange +
/// combine rounds.
pub fn cost_allreduce_rd(net: &NetModel, pt: SelectPoint) -> f64 {
    log2_ceil(pt.p) as f64 * (hop_us(net, pt, pt.bytes) + net.reduce_cost(pt.bytes))
}

/// Rabenseifner: recursive-halving reduce-scatter, then a
/// recursive-doubling allgather of the same halving block sizes.
pub fn cost_allreduce_rabenseifner(net: &NetModel, pt: SelectPoint) -> f64 {
    let mut cost = 0.0;
    let mut blk = pt.bytes;
    for _ in 0..log2_ceil(pt.p) {
        blk = (blk / 2).max(1);
        cost += 2.0 * hop_us(net, pt, blk) + net.reduce_cost(blk);
    }
    cost
}

/// §5.2.4 method 1 at bridge block `bytes` over `nnodes` leaders.
/// The on-node pre-reduction into the shared window is common to both
/// methods and cancels in the ranking, so only the differences are
/// charged: a recursive-doubling bridge allreduce (compute once), plus
/// the extra release synchronization to publish the reduced result to
/// the node (spin release/observe pair bracketed by window syncs).
pub fn cost_method1(net: &NetModel, nnodes: usize, _rpn: usize, bytes: usize) -> f64 {
    let bridge = log2_ceil(nnodes) as f64 * (net.transfer(false, bytes) + net.reduce_cost(bytes));
    bridge + net.spin_release_us + net.spin_poll_us + 2.0 * net.win_sync_us
}

/// §5.2.4 method 2: leaders allgather the per-node inputs over the
/// bridge (recursive doubling), then every node combines all `nnodes`
/// contributions locally — redundant arithmetic and memory traffic
/// (`(nnodes−1)·bytes` streamed per node) that buys away method 1's
/// publish synchronization, hence the small-message winner.
pub fn cost_method2(net: &NetModel, nnodes: usize, _rpn: usize, bytes: usize) -> f64 {
    let bridge: f64 =
        (0..log2_ceil(nnodes)).map(|i| net.transfer(false, (1usize << i) * bytes)).sum();
    bridge + (nnodes.saturating_sub(1)) as f64 * (net.reduce_cost(bytes) + net.memcpy(bytes))
}

// ---------------------------------------------------------------------
// Candidate enumeration (viability-filtered).
// ---------------------------------------------------------------------

/// Segment-size grid for the segmented broadcasts: the static table's
/// own segments plus one step either side, deduplicated.
fn seg_grid(base: usize) -> Vec<usize> {
    let mut v = vec![base / 4, base, base * 4];
    v.retain(|&s| s >= 1024);
    v.dedup();
    v
}

/// Every viable broadcast candidate at `pt`, scored.
pub fn bcast_candidates(net: &NetModel, pt: SelectPoint, t: &Tuning) -> Vec<Candidate<BcastAlgo>> {
    let mut out = vec![Candidate { algo: BcastAlgo::Binomial, cost_us: cost_bcast_binomial(net, pt) }];
    if pt.p > 2 {
        for seg in seg_grid(t.bcast_seg) {
            out.push(Candidate {
                algo: BcastAlgo::SplitBinary { seg },
                cost_us: cost_bcast_split_binary(net, pt, seg),
            });
        }
        for seg in seg_grid(t.pipeline_seg) {
            out.push(Candidate {
                algo: BcastAlgo::Pipeline { seg },
                cost_us: cost_bcast_pipeline(net, pt, seg),
            });
        }
        if pt.bytes >= pt.p {
            out.push(Candidate {
                algo: BcastAlgo::ScatterAllgather,
                cost_us: cost_bcast_scatter_allgather(net, pt),
            });
        }
    }
    out
}

/// Every viable allgather candidate at `pt`, scored. Recursive doubling
/// is enumerated only on power-of-two communicators.
pub fn allgather_candidates(net: &NetModel, pt: SelectPoint) -> Vec<Candidate<AllgatherAlgo>> {
    let mut out = vec![
        Candidate { algo: AllgatherAlgo::Bruck, cost_us: cost_allgather_bruck(net, pt) },
        Candidate { algo: AllgatherAlgo::Ring, cost_us: cost_allgather_ring(net, pt) },
    ];
    if pt.p.is_power_of_two() && pt.p > 1 {
        out.push(Candidate {
            algo: AllgatherAlgo::RecursiveDoubling,
            cost_us: cost_allgather_rd(net, pt),
        });
    }
    out
}

/// Both allreduce candidates, scored (the non-power-of-two fold is
/// shared by both implementations, so it cancels in the ranking).
pub fn allreduce_candidates(net: &NetModel, pt: SelectPoint) -> Vec<Candidate<AllreduceAlgo>> {
    vec![
        Candidate { algo: AllreduceAlgo::RecursiveDoubling, cost_us: cost_allreduce_rd(net, pt) },
        Candidate { algo: AllreduceAlgo::Rabenseifner, cost_us: cost_allreduce_rabenseifner(net, pt) },
    ]
}

/// Both §5.2.4 step-1 methods, scored.
pub fn method_candidates(
    net: &NetModel,
    nnodes: usize,
    rpn: usize,
    bytes: usize,
) -> Vec<Candidate<AllreduceMethod>> {
    vec![
        Candidate { algo: AllreduceMethod::Method1, cost_us: cost_method1(net, nnodes, rpn, bytes) },
        Candidate { algo: AllreduceMethod::Method2, cost_us: cost_method2(net, nnodes, rpn, bytes) },
    ]
}

/// Arg-min over a candidate list (first wins ties — enumeration order
/// is deterministic, so every rank picks the same winner).
pub fn best<A: Copy>(cands: &[Candidate<A>]) -> Candidate<A> {
    let mut win = cands[0];
    for c in &cands[1..] {
        if c.cost_us < win.cost_us {
            win = *c;
        }
    }
    win
}

// ---------------------------------------------------------------------
// Algo <-> name mapping (tuning-table entries, reports).
// ---------------------------------------------------------------------

/// `(name, seg)` of a broadcast algorithm (`seg` = 0 when unsegmented).
pub fn bcast_name(a: BcastAlgo) -> (&'static str, usize) {
    match a {
        BcastAlgo::Binomial => ("binomial", 0),
        BcastAlgo::SplitBinary { seg } => ("split_binary", seg),
        BcastAlgo::Pipeline { seg } => ("pipeline", seg),
        BcastAlgo::ScatterAllgather => ("scatter_allgather", 0),
        BcastAlgo::Auto => ("auto", 0),
    }
}

pub fn allgather_name(a: AllgatherAlgo) -> &'static str {
    match a {
        AllgatherAlgo::Bruck => "bruck",
        AllgatherAlgo::RecursiveDoubling => "recursive_doubling",
        AllgatherAlgo::Ring => "ring",
        AllgatherAlgo::Auto => "auto",
    }
}

pub fn allreduce_name(a: AllreduceAlgo) -> &'static str {
    match a {
        AllreduceAlgo::RecursiveDoubling => "recursive_doubling",
        AllreduceAlgo::Rabenseifner => "rabenseifner",
        AllreduceAlgo::Auto => "auto",
    }
}

pub fn method_name(m: AllreduceMethod) -> &'static str {
    match m {
        AllreduceMethod::Method1 => "method1",
        AllreduceMethod::Method2 => "method2",
        AllreduceMethod::Tuned => "tuned",
    }
}

pub fn parse_bcast(name: &str, seg: usize) -> Option<BcastAlgo> {
    match name {
        "binomial" => Some(BcastAlgo::Binomial),
        "split_binary" if seg > 0 => Some(BcastAlgo::SplitBinary { seg }),
        "pipeline" if seg > 0 => Some(BcastAlgo::Pipeline { seg }),
        "scatter_allgather" => Some(BcastAlgo::ScatterAllgather),
        _ => None,
    }
}

pub fn parse_allgather(name: &str) -> Option<AllgatherAlgo> {
    match name {
        "bruck" => Some(AllgatherAlgo::Bruck),
        "recursive_doubling" => Some(AllgatherAlgo::RecursiveDoubling),
        "ring" => Some(AllgatherAlgo::Ring),
        _ => None,
    }
}

pub fn parse_allreduce(name: &str) -> Option<AllreduceAlgo> {
    match name {
        "recursive_doubling" => Some(AllreduceAlgo::RecursiveDoubling),
        "rabenseifner" => Some(AllreduceAlgo::Rabenseifner),
        _ => None,
    }
}

pub fn parse_method(name: &str) -> Option<AllreduceMethod> {
    match name {
        "method1" => Some(AllreduceMethod::Method1),
        "method2" => Some(AllreduceMethod::Method2),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// The cost-model selector.
// ---------------------------------------------------------------------

/// Picks the registry's cheapest viable candidate at every point —
/// the online (no-measurement) half of the autotuner.
#[derive(Clone, Debug)]
pub struct ModelSelector {
    net: NetModel,
    ranks_per_node: usize,
    tuning: Tuning,
}

impl ModelSelector {
    /// `ranks_per_node` is the topology hint (cores per node of the
    /// cluster being modeled; 16 for the VulcanSb preset).
    pub fn new(net: NetModel, ranks_per_node: usize) -> ModelSelector {
        ModelSelector { net, ranks_per_node: ranks_per_node.max(1), tuning: Tuning::from_env() }
    }

    fn point(&self, p: usize, bytes: usize) -> SelectPoint {
        SelectPoint::new(p, bytes, self.ranks_per_node)
    }

    /// The model this selector scores with.
    pub fn net(&self) -> &NetModel {
        &self.net
    }

    pub fn ranks_per_node(&self) -> usize {
        self.ranks_per_node
    }
}

impl Selector for ModelSelector {
    fn describe(&self) -> String {
        format!("model ({}, {} ranks/node)", self.net.name, self.ranks_per_node)
    }

    fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        if p <= 2 || bytes == 0 {
            return BcastAlgo::Binomial;
        }
        best(&bcast_candidates(&self.net, self.point(p, bytes), &self.tuning)).algo
    }

    fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        if p <= 1 {
            return AllgatherAlgo::Ring;
        }
        best(&allgather_candidates(&self.net, self.point(p, bytes))).algo
    }

    fn allreduce_algo(&self, p: usize, bytes: usize) -> AllreduceAlgo {
        if p <= 1 {
            return AllreduceAlgo::RecursiveDoubling;
        }
        best(&allreduce_candidates(&self.net, self.point(p, bytes))).algo
    }

    fn allreduce_method(&self, bytes: usize) -> AllreduceMethod {
        // Method choice keys on the bridge block; model a nominal
        // two-node bridge (the figure shapes) at this node width.
        best(&method_candidates(&self.net, 2, self.ranks_per_node, bytes)).algo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop as props;

    fn pt(p: usize, bytes: usize) -> SelectPoint {
        SelectPoint::new(p, bytes, 16)
    }

    #[test]
    fn registry_enumerates_only_viable_candidates() {
        let net = NetModel::infiniband();
        // Non-power-of-two: recursive doubling must not be offered.
        for c in allgather_candidates(&net, pt(24, 4096)) {
            assert_ne!(c.algo, AllgatherAlgo::RecursiveDoubling);
        }
        // Power-of-two: it must be.
        assert!(allgather_candidates(&net, pt(32, 4096))
            .iter()
            .any(|c| c.algo == AllgatherAlgo::RecursiveDoubling));
        // p = 2: only binomial broadcast (trees degenerate).
        assert_eq!(bcast_candidates(&net, pt(2, 1 << 20), &Tuning::default()).len(), 1);
    }

    #[test]
    fn costs_are_finite_and_positive() {
        let net = NetModel::aries();
        for p in [2, 5, 8, 24, 127, 1024] {
            for bytes in [1, 800, 64 * 1024, 4 << 20] {
                for c in bcast_candidates(&net, pt(p, bytes), &Tuning::default()) {
                    assert!(c.cost_us.is_finite() && c.cost_us > 0.0, "{:?}", c.algo);
                }
                for c in allgather_candidates(&net, pt(p, bytes)) {
                    assert!(c.cost_us.is_finite() && c.cost_us > 0.0, "{:?}", c.algo);
                }
                for c in allreduce_candidates(&net, pt(p, bytes)) {
                    assert!(c.cost_us.is_finite() && c.cost_us > 0.0, "{:?}", c.algo);
                }
            }
        }
    }

    #[test]
    fn model_reproduces_the_published_crossover_shapes() {
        let m = ModelSelector::new(NetModel::infiniband(), 16);
        // Latency-bound smalls: log-round algorithms.
        assert_eq!(m.allgather_algo(24, 64), AllgatherAlgo::Bruck);
        assert_eq!(m.bcast_algo(32, 256), BcastAlgo::Binomial);
        assert_eq!(m.allreduce_algo(32, 512), AllreduceAlgo::RecursiveDoubling);
        // Bandwidth-bound larges: the bandwidth-optimal family.
        assert_eq!(m.allreduce_algo(32, 4 << 20), AllreduceAlgo::Rabenseifner);
        assert!(matches!(
            m.bcast_algo(32, 4 << 20),
            BcastAlgo::ScatterAllgather | BcastAlgo::Pipeline { .. } | BcastAlgo::SplitBinary { .. }
        ));
        // Method cutoff: method 2 small, method 1 large (§5.2.4).
        assert_eq!(m.allreduce_method(256), AllreduceMethod::Method2);
        assert_eq!(m.allreduce_method(1 << 20), AllreduceMethod::Method1);
    }

    #[test]
    fn model_selector_is_total_and_viable() {
        // Property: every (p, bytes) maps to exactly one viable,
        // bound algorithm under the model selector (the tuned half of
        // the ISSUE-9 satellite property; the static half lives in
        // coll/tuning.rs).
        let m = ModelSelector::new(NetModel::infiniband(), 16);
        props::run(
            "model-selector-total",
            props::default_cases(),
            |r| (1 + r.below(1024), r.below(1 << 20)),
            |&(p, bytes)| {
                let bc = m.bcast_algo(p, bytes);
                if matches!(bc, BcastAlgo::Auto) {
                    return Err(format!("bcast unbound at ({p},{bytes})"));
                }
                let ag = m.allgather_algo(p, bytes);
                if matches!(ag, AllgatherAlgo::Auto) {
                    return Err(format!("allgather unbound at ({p},{bytes})"));
                }
                if ag == AllgatherAlgo::RecursiveDoubling && !p.is_power_of_two() {
                    return Err(format!("RD offered at non-pow2 p={p}"));
                }
                if matches!(m.allreduce_algo(p, bytes), AllreduceAlgo::Auto) {
                    return Err(format!("allreduce unbound at ({p},{bytes})"));
                }
                if matches!(m.allreduce_method(bytes), AllreduceMethod::Tuned) {
                    return Err(format!("method unbound at {bytes}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn names_round_trip() {
        for a in [
            BcastAlgo::Binomial,
            BcastAlgo::SplitBinary { seg: 32 * 1024 },
            BcastAlgo::Pipeline { seg: 128 * 1024 },
            BcastAlgo::ScatterAllgather,
        ] {
            let (n, s) = bcast_name(a);
            assert_eq!(parse_bcast(n, s), Some(a));
        }
        for a in [AllgatherAlgo::Bruck, AllgatherAlgo::RecursiveDoubling, AllgatherAlgo::Ring] {
            assert_eq!(parse_allgather(allgather_name(a)), Some(a));
        }
        for a in [AllreduceAlgo::RecursiveDoubling, AllreduceAlgo::Rabenseifner] {
            assert_eq!(parse_allreduce(allreduce_name(a)), Some(a));
        }
        for m in [AllreduceMethod::Method1, AllreduceMethod::Method2] {
            assert_eq!(parse_method(method_name(m)), Some(m));
        }
    }
}
