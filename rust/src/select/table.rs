//! The versioned persisted tuning table (`TUNING.json`).
//!
//! The follow-up paper (arXiv 2007.06892) ships per-cluster tuned
//! cutoff tables; UCC persists tuner output so "init once" sessions
//! never re-measure a point the cluster has already answered. This
//! module is that artifact: a committed JSON file of range entries
//! (`op`, `p` range, `bytes` range → algorithm) that
//! [`TableSelector`] consults *before* any fallback, `bin/tune_all`
//! regenerates, and CI drift-checks against the registry schema.
//!
//! The crate builds with zero dependencies, so the file format is a
//! strict JSON subset (objects, arrays, strings, non-negative
//! integers) parsed by the ~100-line reader below — the same spirit as
//! the hand-rolled writers in `bench_all`.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coll::allgather::AllgatherAlgo;
use crate::coll::allreduce::AllreduceAlgo;
use crate::coll::bcast::BcastAlgo;
use crate::hybrid::allreduce::AllreduceMethod;

use super::registry;
use super::{sanitize_allgather, Selector};

/// Schema version this build reads and writes. Bump on any change to
/// the entry shape; `load` rejects other versions so a stale committed
/// table fails loudly (the CI drift check) instead of mis-selecting.
pub const TABLE_VERSION: u64 = 1;

/// The op names a table entry may carry.
pub const OPS: [&str; 4] = ["bcast", "allgather", "allreduce", "allreduce_method"];

/// One tuned range: for `op` on communicators of `p_min..=p_max` ranks
/// and messages of `bytes_min..=bytes_max` bytes, run `algo` (with
/// segment `seg` if the algorithm is segmented).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub op: String,
    pub p_min: usize,
    pub p_max: usize,
    pub bytes_min: usize,
    pub bytes_max: usize,
    pub algo: String,
    pub seg: usize,
    /// Provenance: `"model"`, `"race"`, or `"manual"`.
    pub source: String,
}

impl Entry {
    fn matches(&self, op: &str, p: usize, bytes: usize) -> bool {
        self.op == op
            && (self.p_min..=self.p_max).contains(&p)
            && (self.bytes_min..=self.bytes_max).contains(&bytes)
    }
}

/// The in-memory table: version header + ordered entries (first match
/// wins, so more specific ranges go first — `tune_all` emits
/// point-disjoint ranges and order never matters for its output).
#[derive(Clone, Debug, Default)]
pub struct TuningTable {
    /// Model/cluster the numbers were tuned on (e.g. `"infiniband"`).
    pub model: String,
    /// Free-form provenance note.
    pub note: String,
    pub entries: Vec<Entry>,
}

/// Path the default selector loads: `HYMPI_TUNING_TABLE` if set, else
/// `TUNING.json` in the working directory (the repo root under
/// `cargo run`/`cargo test`, since `Cargo.toml` lives there).
pub fn default_path() -> PathBuf {
    std::env::var("HYMPI_TUNING_TABLE").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("TUNING.json"))
}

impl TuningTable {
    pub fn new(model: &str, note: &str) -> TuningTable {
        TuningTable { model: model.to_string(), note: note.to_string(), entries: Vec::new() }
    }

    /// First entry matching `(op, p, bytes)`.
    pub fn lookup(&self, op: &str, p: usize, bytes: usize) -> Option<&Entry> {
        self.entries.iter().find(|e| e.matches(op, p, bytes))
    }

    /// Append a tuned range.
    pub fn push(&mut self, e: Entry) {
        self.entries.push(e);
    }

    /// Validate against the registry schema: known ops, parseable
    /// algorithms, sane ranges. Returns every problem, not just the
    /// first — this is the CI drift check.
    pub fn validate(&self) -> Result<(), Vec<String>> {
        let mut errs = Vec::new();
        for (i, e) in self.entries.iter().enumerate() {
            let at = |msg: String| format!("entry {i} ({}/{}): {msg}", e.op, e.algo);
            if !OPS.contains(&e.op.as_str()) {
                errs.push(at(format!("unknown op {:?}", e.op)));
                continue;
            }
            if e.p_min > e.p_max || e.p_min == 0 {
                errs.push(at(format!("bad p range {}..={}", e.p_min, e.p_max)));
            }
            if e.bytes_min > e.bytes_max {
                errs.push(at(format!("bad bytes range {}..={}", e.bytes_min, e.bytes_max)));
            }
            let known = match e.op.as_str() {
                "bcast" => registry::parse_bcast(&e.algo, e.seg.max(1)).is_some(),
                "allgather" => registry::parse_allgather(&e.algo).is_some(),
                "allreduce" => registry::parse_allreduce(&e.algo).is_some(),
                _ => registry::parse_method(&e.algo).is_some(),
            };
            if !known {
                errs.push(at("algorithm not in the registry".to_string()));
            }
            if matches!(e.algo.as_str(), "split_binary" | "pipeline") && e.seg == 0 {
                errs.push(at("segmented algorithm with seg = 0".to_string()));
            }
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }

    /// Serialize (stable key order, one entry per line — diff-friendly
    /// for the committed artifact).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        let _ = writeln!(s, "  \"version\": {},", TABLE_VERSION);
        let _ = writeln!(s, "  \"model\": \"{}\",", escape(&self.model));
        let _ = writeln!(s, "  \"note\": \"{}\",", escape(&self.note));
        s.push_str("  \"entries\": [");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(if i == 0 { "\n" } else { ",\n" });
            let _ = write!(
                s,
                "    {{\"op\": \"{}\", \"p_min\": {}, \"p_max\": {}, \"bytes_min\": {}, \"bytes_max\": {}, \"algo\": \"{}\", \"seg\": {}, \"source\": \"{}\"}}",
                escape(&e.op), e.p_min, e.p_max, e.bytes_min, e.bytes_max, escape(&e.algo), e.seg, escape(&e.source)
            );
        }
        s.push_str(if self.entries.is_empty() { "]\n}\n" } else { "\n  ]\n}\n" });
        s
    }

    /// Parse a table (strict: unknown versions are errors).
    pub fn from_json(text: &str) -> Result<TuningTable, String> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().ok_or("top level is not an object")?;
        let version = get(obj, "version").and_then(Json::as_u64).ok_or("missing \"version\"")?;
        if version != TABLE_VERSION {
            return Err(format!("table version {version}, this build reads {TABLE_VERSION}"));
        }
        let mut t = TuningTable {
            model: get(obj, "model").and_then(Json::as_str).unwrap_or_default().to_string(),
            note: get(obj, "note").and_then(Json::as_str).unwrap_or_default().to_string(),
            entries: Vec::new(),
        };
        let entries = get(obj, "entries").and_then(Json::as_arr).ok_or("missing \"entries\"")?;
        for (i, e) in entries.iter().enumerate() {
            let o = e.as_obj().ok_or(format!("entry {i} is not an object"))?;
            let us = |k: &str| -> Result<usize, String> {
                get(o, k).and_then(Json::as_u64).map(|v| v as usize).ok_or(format!("entry {i}: missing \"{k}\""))
            };
            let st = |k: &str| -> Result<String, String> {
                get(o, k).and_then(Json::as_str).map(str::to_string).ok_or(format!("entry {i}: missing \"{k}\""))
            };
            t.entries.push(Entry {
                op: st("op")?,
                p_min: us("p_min")?,
                p_max: us("p_max")?,
                bytes_min: us("bytes_min")?,
                bytes_max: us("bytes_max")?,
                algo: st("algo")?,
                seg: us("seg")?,
                source: st("source")?,
            });
        }
        Ok(t)
    }

    pub fn load(path: &Path) -> Result<TuningTable, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&text)
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json()).map_err(|e| format!("{}: {e}", path.display()))
    }
}

fn escape(s: &str) -> String {
    s.chars().flat_map(|c| match c {
        '"' => vec!['\\', '"'],
        '\\' => vec!['\\', '\\'],
        c if (c as u32) < 0x20 => vec![' '],
        c => vec![c],
    }).collect()
}

fn get<'a>(obj: &'a [(String, Json)], key: &str) -> Option<&'a Json> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

/// The JSON subset the table format needs. Numbers are non-negative
/// integers (all table fields are counts/sizes); floats, booleans and
/// null are accepted and ignored-typed so foreign files fail with a
/// field error rather than a parse error.
#[derive(Clone, Debug)]
enum Json {
    Num(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
    Other,
}

impl Json {
    fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    fn parse(text: &str) -> Result<Json, String> {
        let b = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at byte {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => {
            *pos += 1;
            let mut out = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                expect(b, pos, b':')?;
                out.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(out));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut out = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            loop {
                out.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(out));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(c) if c.is_ascii_digit() => {
            let start = *pos;
            while *pos < b.len() && (b[*pos].is_ascii_digit() || matches!(b[*pos], b'.' | b'e' | b'E' | b'+' | b'-')) {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).unwrap();
            Ok(s.parse::<u64>().map(Json::Num).unwrap_or(Json::Other))
        }
        Some(b'-') => {
            *pos += 1;
            while *pos < b.len() && (b[*pos].is_ascii_digit() || matches!(b[*pos], b'.' | b'e' | b'E' | b'+')) {
                *pos += 1;
            }
            Ok(Json::Other)
        }
        Some(_) => {
            for lit in ["true", "false", "null"] {
                if b[*pos..].starts_with(lit.as_bytes()) {
                    *pos += lit.len();
                    return Ok(Json::Other);
                }
            }
            Err(format!("unexpected byte {} in value position", *pos))
        }
        None => Err("unexpected end of input".to_string()),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(&c) => out.push(c as char),
                    None => return Err("dangling escape".to_string()),
                }
                *pos += 1;
            }
            c => {
                // Multi-byte UTF-8 passes through byte-wise intact.
                out.push(c as char);
                *pos += 1;
            }
        }
    }
    Err("unterminated string".to_string())
}

/// Persisted winners first, fallback second: the selector the default
/// global wraps around [`super::StaticSelector`] when a non-empty
/// `TUNING.json` is present, and that [`super::tuner::Autotuner`]
/// layers over the model.
pub struct TableSelector {
    table: TuningTable,
    fallback: Arc<dyn Selector>,
}

impl TableSelector {
    pub fn new(table: TuningTable, fallback: Arc<dyn Selector>) -> TableSelector {
        TableSelector { table, fallback }
    }

    pub fn table(&self) -> &TuningTable {
        &self.table
    }
}

impl Selector for TableSelector {
    fn describe(&self) -> String {
        format!("table ({} entries, {}) over {}", self.table.entries.len(), self.table.model, self.fallback.describe())
    }

    fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        self.table
            .lookup("bcast", p, bytes)
            .and_then(|e| registry::parse_bcast(&e.algo, e.seg))
            .unwrap_or_else(|| self.fallback.bcast_algo(p, bytes))
    }

    fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        let a = self
            .table
            .lookup("allgather", p, bytes)
            .and_then(|e| registry::parse_allgather(&e.algo))
            .unwrap_or_else(|| self.fallback.allgather_algo(p, bytes));
        sanitize_allgather(a, p)
    }

    fn allreduce_algo(&self, p: usize, bytes: usize) -> AllreduceAlgo {
        self.table
            .lookup("allreduce", p, bytes)
            .and_then(|e| registry::parse_allreduce(&e.algo))
            .unwrap_or_else(|| self.fallback.allreduce_algo(p, bytes))
    }

    fn allreduce_method(&self, bytes: usize) -> AllreduceMethod {
        self.table
            .lookup("allreduce_method", 1, bytes)
            .and_then(|e| registry::parse_method(&e.algo))
            .unwrap_or_else(|| self.fallback.allreduce_method(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::StaticSelector;

    fn entry(op: &str, p: (usize, usize), bytes: (usize, usize), algo: &str, seg: usize) -> Entry {
        Entry {
            op: op.to_string(),
            p_min: p.0,
            p_max: p.1,
            bytes_min: bytes.0,
            bytes_max: bytes.1,
            algo: algo.to_string(),
            seg,
            source: "manual".to_string(),
        }
    }

    #[test]
    fn json_round_trips() {
        let mut t = TuningTable::new("infiniband", "unit test");
        t.push(entry("bcast", (3, 64), (0, 2048), "binomial", 0));
        t.push(entry("allgather", (2, 1024), (4096, usize::MAX), "ring", 0));
        let back = TuningTable::from_json(&t.to_json()).expect("round trip");
        assert_eq!(back.model, "infiniband");
        assert_eq!(back.entries, t.entries);
        assert!(back.validate().is_ok());
    }

    #[test]
    fn wrong_version_and_garbage_are_rejected() {
        assert!(TuningTable::from_json("{\"version\": 99, \"entries\": []}").is_err());
        assert!(TuningTable::from_json("not json").is_err());
        assert!(TuningTable::from_json("{\"version\": 1}").is_err()); // no entries key
        // Empty table with the right version is fine.
        let t = TuningTable::from_json("{\"version\": 1, \"model\": \"x\", \"note\": \"\", \"entries\": []}").unwrap();
        assert!(t.entries.is_empty());
    }

    #[test]
    fn validate_flags_schema_drift() {
        let mut t = TuningTable::new("m", "");
        t.push(entry("bcast", (3, 8), (0, 100), "warp_drive", 0)); // unknown algo
        t.push(entry("frobnicate", (1, 8), (0, 100), "ring", 0)); // unknown op
        t.push(entry("bcast", (8, 3), (0, 100), "binomial", 0)); // inverted p range
        t.push(entry("bcast", (3, 8), (0, 100), "pipeline", 0)); // seg = 0
        let errs = t.validate().unwrap_err();
        assert_eq!(errs.len(), 4, "{errs:?}");
    }

    #[test]
    fn table_hits_win_and_misses_fall_back() {
        let mut t = TuningTable::new("m", "");
        t.push(entry("bcast", (2, 1024), (0, usize::MAX), "scatter_allgather", 0));
        // RD persisted for *all* p: sanitize must degrade it off pow2.
        t.push(entry("allgather", (2, 1024), (0, usize::MAX), "recursive_doubling", 0));
        let s = TableSelector::new(t, Arc::new(StaticSelector::default()));
        assert_eq!(s.bcast_algo(8, 100), BcastAlgo::ScatterAllgather);
        assert_eq!(s.allgather_algo(8, 100), AllgatherAlgo::RecursiveDoubling);
        assert_eq!(s.allgather_algo(12, 100), AllgatherAlgo::Ring);
        // No allreduce entries: static fallback decides.
        assert_eq!(s.allreduce_algo(8, 100), StaticSelector::default().allreduce_algo(8, 100));
        assert_eq!(s.allreduce_method(4096), StaticSelector::default().allreduce_method(4096));
    }
}
