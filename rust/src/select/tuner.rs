//! The online autotuner: table first, model second, race when asked.
//!
//! [`Autotuner`] is the selector binaries install for tuned runs. Every
//! query goes through the UCC-shaped decision ladder:
//!
//! 1. **persisted** — a seeded [`TuningTable`] hit answers immediately
//!    (winners from a previous `tune_all` run, "consulted before
//!    re-tuning");
//! 2. **modeled** — otherwise the [`ModelSelector`] arg-mins the
//!    registry's closed-form costs, and the answer is memoized into the
//!    live table so the point is decided once per process;
//! 3. **raced** — empirical timing never happens implicitly inside a
//!    query (selectors are called from `*_init` hot paths); instead
//!    `PlanCache::plan_raced`, `tune_all` and `bench_all --tuned` time
//!    candidates on live persistent handles and feed winners back via
//!    [`Autotuner::record`] / the generic [`race`] fold.
//!
//! [`PinnedSelector`] is the race harness's lever: it forces one
//! candidate for one op while delegating everything else, so a driver
//! can install it, time a figure point, and restore the previous
//! selector.

use std::sync::{Arc, Mutex};

use crate::coll::allgather::AllgatherAlgo;
use crate::coll::allreduce::AllreduceAlgo;
use crate::coll::bcast::BcastAlgo;
use crate::mpi::net::NetModel;

use super::registry::{self, ModelSelector};
use super::table::{Entry, TuningTable};
use super::{sanitize_allgather, Selector};
use crate::hybrid::allreduce::AllreduceMethod;

/// How the tuner binds a winner at `*_init` time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneMode {
    /// Closed-form α-β estimates only (no measurement; deterministic).
    CostModel,
    /// Race candidates with `iters` timed warm-up invocations each
    /// (drivers call `PlanCache::plan_raced`; queries still answer from
    /// table-then-model until a race result is recorded).
    Race { iters: usize },
}

/// Result of one race: per-candidate mean times and the arg-min.
#[derive(Clone, Debug)]
pub struct RaceOutcome {
    /// Index of the winner in the candidate list.
    pub winner: usize,
    /// `(label, mean µs)` per candidate, in enumeration order.
    pub times: Vec<(String, f64)>,
}

impl RaceOutcome {
    pub fn winner_label(&self) -> &str {
        &self.times[self.winner].0
    }
    pub fn winner_us(&self) -> f64 {
        self.times[self.winner].1
    }
}

/// Fold measured candidate times into a winner: strict arg-min with
/// first-index tie-break. Callers that race *collectively* must pass
/// times already agreed across ranks (e.g. a max-reduction of local
/// means) so every rank folds identical inputs — enumeration order is
/// deterministic, so the winner index then is too.
pub fn race(times: Vec<(String, f64)>) -> RaceOutcome {
    assert!(!times.is_empty(), "race over zero candidates");
    let mut winner = 0;
    for (i, t) in times.iter().enumerate().skip(1) {
        if t.1 < times[winner].1 {
            winner = i;
        }
    }
    RaceOutcome { winner, times }
}

/// The online autotuner (decision ladder above).
pub struct Autotuner {
    model: ModelSelector,
    table: Mutex<TuningTable>,
    mode: TuneMode,
}

impl Autotuner {
    /// Tuner over `net` with `ranks_per_node` topology hint, starting
    /// from an empty live table.
    pub fn new(net: NetModel, ranks_per_node: usize, mode: TuneMode) -> Autotuner {
        let table = TuningTable::new(net.name, "live autotuner memo");
        Autotuner { model: ModelSelector::new(net, ranks_per_node), table: Mutex::new(table), mode }
    }

    /// Seed the live table with persisted winners (loaded
    /// `TUNING.json`): those points answer from the table and are never
    /// re-tuned.
    pub fn seed(self, table: TuningTable) -> Autotuner {
        *self.table.lock().expect("tuner table") = table;
        self
    }

    pub fn mode(&self) -> TuneMode {
        self.mode
    }

    /// Snapshot of the live table (persisted winners + everything
    /// memoized or recorded since) — what `tune_all` writes out.
    pub fn export_table(&self) -> TuningTable {
        self.table.lock().expect("tuner table").clone()
    }

    /// Record an empirically raced winner for a point (source
    /// `"race"`); subsequent queries for exactly that point answer from
    /// the table.
    pub fn record(&self, op: &str, p: usize, bytes: usize, algo: &str, seg: usize) {
        let mut t = self.table.lock().expect("tuner table");
        t.entries.insert(
            0, // races outrank memoized model picks: first match wins
            Entry {
                op: op.to_string(),
                p_min: p,
                p_max: p,
                bytes_min: bytes,
                bytes_max: bytes,
                algo: algo.to_string(),
                seg,
                source: "race".to_string(),
            },
        );
    }

    fn memo(&self, op: &str, p: usize, bytes: usize, algo: &str, seg: usize) {
        self.table.lock().expect("tuner table").push(Entry {
            op: op.to_string(),
            p_min: p,
            p_max: p,
            bytes_min: bytes,
            bytes_max: bytes,
            algo: algo.to_string(),
            seg,
            source: "model".to_string(),
        });
    }

    fn hit(&self, op: &str, p: usize, bytes: usize) -> Option<(String, usize)> {
        let t = self.table.lock().expect("tuner table");
        t.lookup(op, p, bytes).map(|e| (e.algo.clone(), e.seg))
    }
}

impl Selector for Autotuner {
    fn describe(&self) -> String {
        let n = self.table.lock().expect("tuner table").entries.len();
        format!("autotuner ({:?}, {} table entries, model {})", self.mode, n, self.model.net().name)
    }

    fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        if let Some((algo, seg)) = self.hit("bcast", p, bytes) {
            if let Some(a) = registry::parse_bcast(&algo, seg) {
                return a;
            }
        }
        let a = self.model.bcast_algo(p, bytes);
        let (name, seg) = registry::bcast_name(a);
        self.memo("bcast", p, bytes, name, seg);
        a
    }

    fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        if let Some((algo, _)) = self.hit("allgather", p, bytes) {
            if let Some(a) = registry::parse_allgather(&algo) {
                return sanitize_allgather(a, p);
            }
        }
        let a = self.model.allgather_algo(p, bytes);
        self.memo("allgather", p, bytes, registry::allgather_name(a), 0);
        sanitize_allgather(a, p)
    }

    fn allreduce_algo(&self, p: usize, bytes: usize) -> AllreduceAlgo {
        if let Some((algo, _)) = self.hit("allreduce", p, bytes) {
            if let Some(a) = registry::parse_allreduce(&algo) {
                return a;
            }
        }
        let a = self.model.allreduce_algo(p, bytes);
        self.memo("allreduce", p, bytes, registry::allreduce_name(a), 0);
        a
    }

    fn allreduce_method(&self, bytes: usize) -> AllreduceMethod {
        if let Some((algo, _)) = self.hit("allreduce_method", 1, bytes) {
            if let Some(m) = registry::parse_method(&algo) {
                return m;
            }
        }
        let m = self.model.allreduce_method(bytes);
        self.memo("allreduce_method", 1, bytes, registry::method_name(m), 0);
        m
    }
}

/// Forces one candidate for one (or more) ops, delegating the rest —
/// the lever race harnesses use to time a specific candidate through
/// the normal `Auto` path.
pub struct PinnedSelector {
    inner: Arc<dyn Selector>,
    bcast: Option<BcastAlgo>,
    allgather: Option<AllgatherAlgo>,
    allreduce: Option<AllreduceAlgo>,
    method: Option<AllreduceMethod>,
}

impl PinnedSelector {
    pub fn over(inner: Arc<dyn Selector>) -> PinnedSelector {
        PinnedSelector { inner, bcast: None, allgather: None, allreduce: None, method: None }
    }

    pub fn pin_bcast(mut self, a: BcastAlgo) -> PinnedSelector {
        self.bcast = Some(a);
        self
    }
    pub fn pin_allgather(mut self, a: AllgatherAlgo) -> PinnedSelector {
        self.allgather = Some(a);
        self
    }
    pub fn pin_allreduce(mut self, a: AllreduceAlgo) -> PinnedSelector {
        self.allreduce = Some(a);
        self
    }
    pub fn pin_method(mut self, m: AllreduceMethod) -> PinnedSelector {
        self.method = Some(m);
        self
    }
}

impl Selector for PinnedSelector {
    fn describe(&self) -> String {
        format!("pinned over {}", self.inner.describe())
    }
    fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        self.bcast.unwrap_or_else(|| self.inner.bcast_algo(p, bytes))
    }
    fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        sanitize_allgather(self.allgather.unwrap_or_else(|| self.inner.allgather_algo(p, bytes)), p)
    }
    fn allreduce_algo(&self, p: usize, bytes: usize) -> AllreduceAlgo {
        self.allreduce.unwrap_or_else(|| self.inner.allreduce_algo(p, bytes))
    }
    fn allreduce_method(&self, bytes: usize) -> AllreduceMethod {
        self.method.unwrap_or_else(|| self.inner.allreduce_method(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::StaticSelector;

    #[test]
    fn race_folds_argmin_with_first_tie_break() {
        let out = race(vec![
            ("ring".to_string(), 8.0),
            ("bruck".to_string(), 3.0),
            ("rd".to_string(), 3.0),
        ]);
        assert_eq!(out.winner, 1);
        assert_eq!(out.winner_label(), "bruck");
        assert_eq!(out.winner_us(), 3.0);
    }

    #[test]
    fn tuner_memoizes_and_seeded_points_are_never_retuned() {
        let tuner = Autotuner::new(NetModel::infiniband(), 16, TuneMode::CostModel);
        let a = tuner.bcast_algo(32, 4096);
        let b = tuner.bcast_algo(32, 4096);
        assert_eq!(a, b);
        // One table entry per distinct point, not per query.
        assert_eq!(tuner.export_table().entries.len(), 1);

        // Seed a deliberately contrarian winner: the table must answer.
        let mut seed = TuningTable::new("test", "");
        seed.push(Entry {
            op: "allreduce".to_string(),
            p_min: 2,
            p_max: 1024,
            bytes_min: 0,
            bytes_max: usize::MAX,
            algo: "rabenseifner".to_string(),
            seg: 0,
            source: "manual".to_string(),
        });
        let tuner = Autotuner::new(NetModel::infiniband(), 16, TuneMode::CostModel).seed(seed);
        assert_eq!(tuner.allreduce_algo(8, 16), AllreduceAlgo::Rabenseifner);
        // Seeded range, not a memo: table unchanged by the query.
        assert_eq!(tuner.export_table().entries.len(), 1);
    }

    #[test]
    fn recorded_race_winner_outranks_model_memo() {
        let tuner = Autotuner::new(NetModel::infiniband(), 16, TuneMode::Race { iters: 3 });
        let modeled = tuner.allgather_algo(24, 512);
        let forced = match modeled {
            AllgatherAlgo::Ring => "bruck",
            _ => "ring",
        };
        tuner.record("allgather", 24, 512, forced, 0);
        assert_ne!(tuner.allgather_algo(24, 512), modeled);
        let t = tuner.export_table();
        assert_eq!(t.entries[0].source, "race");
        assert!(t.validate().is_ok());
    }

    #[test]
    fn pinned_selector_forces_only_the_pinned_op() {
        let inner = Arc::new(StaticSelector::default());
        let pin = PinnedSelector::over(inner.clone()).pin_bcast(BcastAlgo::ScatterAllgather);
        assert_eq!(pin.bcast_algo(8, 100), BcastAlgo::ScatterAllgather);
        assert_eq!(pin.allreduce_algo(8, 100), inner.allreduce_algo(8, 100));
        // Pinning RD allgather still sanitizes on non-pow2.
        let pin = PinnedSelector::over(inner).pin_allgather(AllgatherAlgo::RecursiveDoubling);
        assert_eq!(pin.allgather_algo(12, 100), AllgatherAlgo::Ring);
    }
}
