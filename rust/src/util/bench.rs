//! A small criterion-style benchmark runner.
//!
//! `cargo bench` targets in this crate use `harness = false` and drive this
//! runner: warmup, repeated timed batches, outlier-robust statistics, and a
//! one-line-per-benchmark report. It exists because the build is offline
//! (criterion is unavailable) — the interface mirrors the subset of
//! criterion we need.

use super::stats::Summary;
use std::time::Instant;

/// Result of one benchmark: wall-clock statistics per iteration (ns).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub per_iter_ns: Summary,
    pub iters: u64,
}

impl BenchStats {
    fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} us", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<48} time: [{} {} {}]  (sd {:.1}%, n={})",
            self.name,
            Self::human(self.per_iter_ns.min),
            Self::human(self.per_iter_ns.mean),
            Self::human(self.per_iter_ns.max),
            self.per_iter_ns.rsd_pct(),
            self.per_iter_ns.n,
        )
    }
}

/// Benchmark runner: collects samples of `batch` iterations each.
pub struct BenchRunner {
    /// Target wall-clock per benchmark (seconds). Default 2.0.
    pub target_secs: f64,
    /// Number of statistical samples. Default 20.
    pub samples: usize,
    /// Warmup time (seconds). Default 0.5.
    pub warmup_secs: f64,
    results: Vec<BenchStats>,
}

impl Default for BenchRunner {
    fn default() -> Self {
        // Honor HYMPI_BENCH_FAST=1 for CI-speed runs.
        let fast = std::env::var("HYMPI_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        BenchRunner {
            target_secs: if fast { 0.2 } else { 2.0 },
            samples: if fast { 5 } else { 20 },
            warmup_secs: if fast { 0.05 } else { 0.5 },
            results: Vec::new(),
        }
    }
}

impl BenchRunner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Benchmark `f`, timing batches sized so each sample lasts about
    /// `target_secs / samples`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed().as_secs_f64() < self.warmup_secs || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est_ns = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
        let per_sample_ns = self.target_secs * 1e9 / self.samples as f64;
        let batch = ((per_sample_ns / est_ns).ceil() as u64).max(1);

        let mut per_iter = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64;
            per_iter.push(dt / batch as f64);
            total_iters += batch;
        }
        let stats = BenchStats {
            name: name.to_string(),
            per_iter_ns: Summary::of(&per_iter),
            iters: total_iters,
        };
        println!("{stats}");
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Run a "figure" generator once and report its wall time; used by the
    /// bench binaries that regenerate full paper tables (their interesting
    /// output is the table itself, not the latency of producing it).
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            per_iter_ns: Summary::of(&[dt]),
            iters: 1,
        };
        println!("{stats}");
        self.results.push(stats);
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut r = BenchRunner { target_secs: 0.05, samples: 4, warmup_secs: 0.005, results: vec![] };
        let mut x = 0u64;
        let s = r.bench("noop-ish", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(s.per_iter_ns.mean > 0.0);
        assert!(s.per_iter_ns.mean < 1e7, "a nop add should not take 10 ms");
        assert_eq!(s.name, "noop-ish");
    }

    #[test]
    fn run_once_records_single_sample() {
        let mut r = BenchRunner { target_secs: 0.01, samples: 2, warmup_secs: 0.001, results: vec![] };
        r.run_once("one-shot", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert_eq!(r.results().len(), 1);
        assert!(r.results()[0].per_iter_ns.mean >= 2e6 * 0.5);
    }

    #[test]
    fn human_formatting() {
        assert_eq!(BenchStats::human(500.0), "500.0 ns");
        assert_eq!(BenchStats::human(2_500.0), "2.50 us");
        assert_eq!(BenchStats::human(3_000_000.0), "3.00 ms");
        assert!(BenchStats::human(2e9).ends_with(" s"));
    }
}
