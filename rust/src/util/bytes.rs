//! Byte/typed-slice conversions for collective payloads.
//!
//! The substrate moves raw bytes (like MPI does); apps work in typed
//! elements. These helpers are the only place the reinterpretation happens,
//! restricted to plain-old-data element types via the sealed [`Pod`] trait.

/// Marker for plain-old-data element types that may be viewed as bytes.
///
/// Safety: implementors must be `#[repr(C)]`-compatible primitives with no
/// padding and no invalid bit patterns.
pub unsafe trait Pod: Copy + Default + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// View a typed slice as bytes.
pub fn to_bytes<T: Pod>(v: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// View a byte slice as a typed slice. Panics if the length is not a
/// multiple of `size_of::<T>()` or the pointer is misaligned for `T`.
pub fn from_bytes<T: Pod>(b: &[u8]) -> &[T] {
    let sz = std::mem::size_of::<T>();
    assert_eq!(b.len() % sz, 0, "byte length {} not a multiple of {}", b.len(), sz);
    assert_eq!(b.as_ptr() as usize % std::mem::align_of::<T>(), 0, "misaligned cast");
    unsafe { std::slice::from_raw_parts(b.as_ptr() as *const T, b.len() / sz) }
}

/// View a mutable byte slice as a typed mutable slice. Panics on length
/// remainder or misalignment.
pub fn from_bytes_mut<T: Pod>(b: &mut [u8]) -> &mut [T] {
    let sz = std::mem::size_of::<T>();
    assert_eq!(b.len() % sz, 0, "byte length {} not a multiple of {}", b.len(), sz);
    assert_eq!(b.as_ptr() as usize % std::mem::align_of::<T>(), 0, "misaligned cast");
    unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut T, b.len() / sz) }
}

/// Copy a byte slice into a typed vector (alignment-safe).
pub fn cast_slice<T: Pod>(b: &[u8]) -> Vec<T> {
    let sz = std::mem::size_of::<T>();
    assert_eq!(b.len() % sz, 0, "byte length {} not a multiple of {}", b.len(), sz);
    let n = b.len() / sz;
    let mut out = vec![T::default(); n];
    unsafe {
        std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, b.len());
    }
    out
}

/// View a typed mutable slice as mutable bytes.
pub fn cast_slice_mut<T: Pod>(v: &mut [T]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, std::mem::size_of_val(v)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f64() {
        let v = vec![1.5f64, -2.25, 1e300];
        let b = to_bytes(&v);
        assert_eq!(b.len(), 24);
        let back: Vec<f64> = cast_slice(b);
        assert_eq!(back, v);
    }

    #[test]
    fn from_bytes_aligned_view() {
        let v = vec![7i64, -9];
        let b = to_bytes(&v);
        let view: &[i64] = from_bytes(b);
        assert_eq!(view, &[7, -9]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn bad_length_panics() {
        let b = [0u8; 7];
        let _: Vec<f64> = cast_slice(&b);
    }

    #[test]
    fn cast_slice_handles_unaligned() {
        // cast_slice copies, so an unaligned source must work.
        let raw = [0u8; 17];
        let _: Vec<f64> = cast_slice(&raw[1..]); // 16 bytes, arbitrary alignment
    }

    #[test]
    fn mut_roundtrip() {
        let mut v = vec![0u32; 4];
        cast_slice_mut(&mut v).copy_from_slice(&[1, 0, 0, 0, 2, 0, 0, 0, 3, 0, 0, 0, 4, 0, 0, 0]);
        assert_eq!(v, vec![1, 2, 3, 4]);
    }
}
