//! Minimal error type backing the crate-wide [`crate::Result`] alias.
//!
//! The default build has **zero external crates** (no registry access at
//! build time), so the role `anyhow` plays in dependency-rich projects is
//! filled by this string-carrying error: cheap construction from message
//! formatting, `From` conversions for the std error types the crate
//! actually produces, and `std::error::Error` so callers can box it.

use std::fmt;

/// A message-carrying error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

/// Format an [`Error`] in place, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn io_error_converts() {
        fn fails() -> crate::Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        let e = fails().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn err_macro_formats() {
        let name = "fig99";
        let e = err!("unknown figure '{name}'");
        assert_eq!(e.to_string(), "unknown figure 'fig99'");
    }
}
