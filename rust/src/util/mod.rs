//! Self-contained utility substrates.
//!
//! The default build is fully offline with **zero external crates** (the
//! optional `xla`/`anyhow` pair lives behind the `pjrt` feature), so the
//! pieces a normal project would pull from crates.io — RNG, statistics,
//! a criterion-style benchmark runner, a property-testing harness, the
//! error type — are implemented here.

pub mod bench;
pub mod bytes;
pub mod error;
pub mod quickprop;
pub mod rng;
pub mod stats;

pub use bench::{BenchRunner, BenchStats};
pub use bytes::{cast_slice, cast_slice_mut, from_bytes, from_bytes_mut, to_bytes};
pub use error::Error;
pub use rng::Rng;
pub use stats::Summary;

/// Alias used by the reduction macros: view bytes as `&[T]`.
pub fn bytes_view<T: bytes::Pod>(b: &[u8]) -> &[T] {
    from_bytes(b)
}

/// Alias used by the reduction macros: view bytes as `&mut [T]`.
pub fn bytes_mut_view<T: bytes::Pod>(b: &mut [u8]) -> &mut [T] {
    from_bytes_mut(b)
}
