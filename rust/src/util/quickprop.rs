//! A miniature property-testing harness (proptest is unavailable offline).
//!
//! `props::run` draws `cases` random inputs from a generator closure and
//! checks a property; on failure it reports the seed and the case index so
//! the exact failing input can be reproduced deterministically with
//! [`reproduce`]. No shrinking — failing inputs in this crate are small by
//! construction (ranks, node shapes, message sizes).

use super::rng::Rng;

/// Default number of cases per property (override with HYMPI_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("HYMPI_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run a property over `cases` random inputs.
///
/// `gen` draws an input from the RNG; `prop` returns `Err(reason)` on
/// violation. Panics with seed/case diagnostics on the first failure.
pub fn run<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = std::env::var("HYMPI_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        // Independent stream per case => any single case is reproducible.
        let mut rng = Rng::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}):\n  input: {input:?}\n  reason: {reason}\n  reproduce with HYMPI_PROP_SEED={seed} and case index {case}"
            );
        }
    }
}

/// Re-draw the input of a specific failing case (for debugging).
pub fn reproduce<T>(seed: u64, case: usize, mut gen: impl FnMut(&mut Rng) -> T) -> T {
    let mut rng = Rng::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
    gen(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run("sum-commutes", 32, |r| (r.below(100), r.below(100)), |&(a, b)| {
            count += 1;
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_diagnostics() {
        run("always-fails", 8, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn reproduce_matches_run_stream() {
        let seed = 0xC0FFEE_u64;
        let drawn = reproduce(seed, 3, |r| r.next_u64());
        let again = reproduce(seed, 3, |r| r.next_u64());
        assert_eq!(drawn, again);
    }
}
