//! Deterministic pseudo-random number generation (xoshiro256**, seeded via
//! splitmix64). Used for synthetic workloads (BPMF data), property tests
//! and payload fuzzing — determinism matters more than cryptographic
//! quality, and reproducibility across runs is a hard requirement for the
//! experiment harness.

/// xoshiro256** generator. Deterministic, fast, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `usize` in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)` (f64).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a byte buffer with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            let n = rem.len();
            rem.copy_from_slice(&b[..n]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(123);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(5);
        let mut buf = vec![0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
