//! Summary statistics for latency samples (mean / stddev / min / max /
//! percentiles) — the paper reports means over 10 000 OSU-style iterations
//! with "standard deviations of only a few percentages", so both are
//! first-class here.

/// Summary statistics over a sample of f64 observations (microseconds in
/// most of this crate).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. Returns a zeroed summary for an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, mean: 0.0, stddev: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }

    /// Relative standard deviation in percent (0 when the mean is 0).
    pub fn rsd_pct(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { 100.0 * self.stddev / self.mean }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean={:.3}us sd={:.3} min={:.3} p50={:.3} p95={:.3} max={:.3} (n={})",
            self.mean, self.stddev, self.min, self.p50, self.p95, self.max, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[4.0]);
        assert_eq!(s.mean, 4.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min, 4.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        // sample stddev of 1..5 = sqrt(2.5)
        assert!((s.stddev - 2.5f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 499.5).abs() <= 1.0);
    }

    #[test]
    fn rsd_pct_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]);
        assert_eq!(s.rsd_pct(), 0.0);
    }
}
