//! Integration tests for the exhaustive interleaving model checker
//! (ISSUE 10, DESIGN.md §6c): real exported schedules explored clean
//! under every reduction mode, mutation tests showing a dropped Arrive,
//! a skipped yellow release and a stale-epoch acceptance each produce a
//! minimal *replayable* counterexample trace naming the interleaving,
//! fault choice points (≤ 2 kills) staying deadlock-free, and the
//! shrink-agreement protocol model — including one exported from a live
//! post-death session — converging under ≤ 2 overlapping deaths with
//! correct root re-election.

use hympi::analysis::dpor::{explore, replay, Budget, Reduction, Violation};
use hympi::analysis::explore::{ScheduleModel, ShrinkModel, ShrinkMutation};
use hympi::analysis::schedule::{RankSchedule, StageModel};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{
    AllreduceMethod, HybridCtx, LeaderPolicy, Reelection, RootPolicy, SyncScheme,
};
use hympi::mpi::{Datatype, FaultPlan, ReduceOp};

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Export one handle's all-rank schedule set on the small [2, 1]
/// exploration shape (3 ranks — full state enumeration stays tiny).
fn export<F>(root: usize, build: F) -> Vec<RankSchedule>
where
    F: Fn(&std::rc::Rc<HybridCtx>, &mut hympi::mpi::env::ProcEnv) -> hympi::hybrid::HyColl
        + Send
        + Sync
        + 'static,
{
    let report = SimCluster::new(spec(&[2, 1])).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = build(&ctx, env);
        let s = h.export_schedule(root);
        env.barrier(&w);
        h.free(env);
        s
    });
    report.outputs
}

fn allgather(scheme: SyncScheme) -> Vec<RankSchedule> {
    export(0, move |ctx, env| ctx.allgather_init(env, 64, scheme))
}

// ---- real exports, every reduction mode --------------------------------

#[test]
fn real_exports_explore_clean_in_every_mode() {
    for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
        let sets = [
            ("allgather", allgather(scheme)),
            (
                "bcast fixed d2",
                export(2, move |ctx, env| {
                    ctx.bcast_init_split(env, 96, scheme, RootPolicy::Fixed(2), 2)
                }),
            ),
            (
                "allreduce m1",
                export(0, move |ctx, env| {
                    ctx.allreduce_init(
                        env,
                        Datatype::F64,
                        ReduceOp::Sum,
                        64,
                        AllreduceMethod::Method1,
                        scheme,
                    )
                }),
            ),
        ];
        for (name, set) in &sets {
            for red in [Reduction::Exhaustive, Reduction::Dpor, Reduction::DporCached] {
                let m = if red == Reduction::Exhaustive {
                    // Conflict checking is exact only under full state
                    // enumeration (DESIGN.md §6c).
                    ScheduleModel::from_handle(set).with_conflict_check()
                } else {
                    ScheduleModel::from_handle(set)
                };
                let r = explore(&m, red, &Budget::smoke());
                assert!(r.complete, "{name} {scheme:?} {red:?} must finish in budget");
                assert!(
                    r.counterexample.is_none(),
                    "{name} {scheme:?} {red:?}: {}",
                    r.counterexample.unwrap()
                );
                assert!(r.terminals >= 1, "{name} {scheme:?} {red:?} reached no terminal");
            }
        }
    }
}

#[test]
fn dpor_explores_fewer_transitions_than_exhaustive() {
    let set = allgather(SyncScheme::Barrier);
    let ex = explore(&ScheduleModel::from_handle(&set), Reduction::Exhaustive, &Budget::smoke());
    let dp = explore(&ScheduleModel::from_handle(&set), Reduction::DporCached, &Budget::smoke());
    assert!(ex.complete && dp.complete);
    assert!(
        dp.transitions < ex.transitions,
        "DPOR must reduce: {} (dpor) vs {} (exhaustive)",
        dp.transitions,
        ex.transitions
    );
}

// ---- mutation: dropped Arrive ------------------------------------------

#[test]
fn dropped_arrive_yields_minimal_replayable_deadlock_trace() {
    let mut set = allgather(SyncScheme::Barrier);
    let i = set[1]
        .stages
        .iter()
        .position(|st| matches!(st, StageModel::Arrive { .. }))
        .expect("allgather opens with a red sync on every rank");
    set[1].stages[i] = StageModel::Skip;
    for red in [Reduction::Exhaustive, Reduction::Dpor, Reduction::DporCached] {
        let m = ScheduleModel::from_handle(&set);
        let r = explore(&m, red, &Budget::smoke());
        let cex = r.counterexample.unwrap_or_else(|| panic!("{red:?} must find the deadlock"));
        let Violation::Deadlock { blocked } = &cex.violation else {
            panic!("{red:?}: expected a deadlock, got {}", cex.violation)
        };
        // The corrupted rank is stuck at its Await; the trace names the
        // interleaving step by step and replays to the same violation.
        assert!(
            blocked.iter().any(|b| b.starts_with("rank 1") && b.contains("AwaitGroup")),
            "{red:?}: blocked set must name rank 1's await: {blocked:?}"
        );
        assert_eq!(cex.trace.len(), cex.steps.len());
        assert!(cex.steps.iter().all(|s| s.starts_with("rank ")));
        let replayed = replay(&m, &cex.trace).expect("the emitted trace must reproduce");
        assert!(matches!(replayed, Violation::Deadlock { .. }));
    }
}

// ---- mutation: skipped yellow release ----------------------------------

#[test]
fn skipped_yellow_release_yields_minimal_replayable_deadlock_trace() {
    let mut set = allgather(SyncScheme::Spin);
    let (r, i) = set
        .iter()
        .enumerate()
        .find_map(|(r, sched)| {
            sched
                .stages
                .iter()
                .position(|st| matches!(st, StageModel::Post { .. }))
                .map(|i| (r, i))
        })
        .expect("the primary leader carries the yellow post");
    set[r].stages[i] = StageModel::Skip;
    let m = ScheduleModel::from_handle(&set);
    let rep = explore(&m, Reduction::Exhaustive, &Budget::smoke());
    let cex = rep.counterexample.expect("the skipped release must deadlock some interleaving");
    let Violation::Deadlock { blocked } = &cex.violation else {
        panic!("expected a deadlock, got {}", cex.violation)
    };
    assert!(
        blocked.iter().any(|b| b.contains("WaitFlag")),
        "the stuck yellow waits must be named: {blocked:?}"
    );
    let replayed = replay(&m, &cex.trace).expect("the emitted trace must reproduce");
    assert!(matches!(replayed, Violation::Deadlock { .. }));
}

// ---- co-enabled conflicting accesses -----------------------------------

#[test]
fn misranged_access_surfaces_as_a_co_enabled_conflict() {
    // Corrupt rank 1's step-1 input read to land on the leader's reduce
    // scratch (offset 2·msize on the node-0 window): after the node
    // reduce both ranks' remaining accesses are concurrent, so some
    // interleaving co-enables the child's read with the leader's scratch
    // write — the class of bug the over-approximated access ranges are
    // meant to catch statically.
    let mut set = export(0, move |ctx, env| {
        ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            64,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        )
    });
    let acc = set[1]
        .stages
        .iter_mut()
        .find_map(|st| match st {
            StageModel::Work { accesses, .. } if !accesses.is_empty() => Some(&mut accesses[0]),
            _ => None,
        })
        .expect("allreduce step 1 reads every rank's input block");
    acc.offset = 2 * 64; // node-0 l_off: shmem_size (2) * msize (64)
    let m = ScheduleModel::from_handle(&set).with_conflict_check();
    let rep = explore(&m, Reduction::Exhaustive, &Budget::smoke());
    let cex = rep.counterexample.expect("the misranged read must conflict with the scratch write");
    let Violation::Conflict { first, second } = &cex.violation else {
        panic!("expected a conflict, got {}", cex.violation)
    };
    assert!(first.contains("Access") && second.contains("Access"), "{first} / {second}");
    assert!(replay(&m, &cex.trace).is_some(), "the conflict trace must reproduce");
}

// ---- fault choice points ------------------------------------------------

#[test]
fn fault_choice_points_stay_deadlock_free() {
    // Any of the three ranks may die before any of its remaining
    // micro-ops, up to two deaths per execution. A stuck state behind a
    // death is a *detected failure* terminal, not a deadlock — the
    // explorer must come back clean.
    for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
        let set = allgather(scheme);
        let m = ScheduleModel::from_handle(&set).with_kills(&[0, 1, 2], 2);
        let r = explore(&m, Reduction::Exhaustive, &Budget::smoke());
        assert!(r.complete, "{scheme:?} fault exploration must finish in budget");
        assert!(r.counterexample.is_none(), "{scheme:?}: {}", r.counterexample.unwrap());
    }
}

// ---- shrink protocol: convergence --------------------------------------

#[test]
fn shrink_agreement_converges_under_two_overlapping_deaths() {
    // 3+2 members, rank 3 already registered dead; the coordinator (0)
    // and the node-1 survivor (4) may additionally die at *any* point of
    // the agreement. Every interleaving must converge to agreement on
    // the true survivor set, with the re-elected root landing on the
    // lowest survivor of dead root 3's node.
    let m = ShrinkModel::new(&[0, 1, 2, 3, 4], &[0, 0, 0, 1, 1], &[3])
        .with_root(3)
        .with_kills(&[0, 4], 2);
    let r = explore(&m, Reduction::Exhaustive, &Budget::smoke());
    assert!(r.complete, "shrink exploration must finish in budget");
    assert!(r.counterexample.is_none(), "{}", r.counterexample.unwrap());
    assert!(r.terminals >= 1);
}

// ---- mutation: stale-epoch acceptance ----------------------------------

#[test]
fn stale_epoch_acceptance_yields_minimal_replayable_protocol_trace() {
    // Round 1 completes for one child while the other's ack is still in
    // flight; the coordinator then dies, the slow child restarts under
    // the new scope — and, with the scope filter disabled, accepts the
    // superseded ack. The unmutated model on the identical configuration
    // is clean: the scope filter is exactly what prevents this.
    let members = [0usize, 1, 2, 3];
    let nodes = [0usize, 0, 1, 1];
    let clean = ShrinkModel::new(&members, &nodes, &[3]).with_kills(&[0], 1);
    let r = explore(&clean, Reduction::Exhaustive, &Budget::smoke());
    assert!(r.complete && r.counterexample.is_none(), "scope filter keeps the protocol clean");

    let mutant = ShrinkModel::new(&members, &nodes, &[3])
        .with_kills(&[0], 1)
        .with_mutation(ShrinkMutation::AcceptStale);
    let rep = explore(&mutant, Reduction::Exhaustive, &Budget::smoke());
    let cex = rep.counterexample.expect("stale acceptance must be caught");
    let Violation::Protocol { detail } = &cex.violation else {
        panic!("expected a protocol violation, got {}", cex.violation)
    };
    assert!(detail.contains("stale"), "the violation names the stale acceptance: {detail}");
    assert!(!cex.steps.is_empty(), "the trace names the interleaving");
    let replayed = replay(&mutant, &cex.trace).expect("the emitted trace must reproduce");
    assert!(matches!(replayed, Violation::Protocol { .. }));
}

// ---- mutation: skipped restart-on-death --------------------------------

#[test]
fn skipped_restart_on_death_fails_convergence() {
    // Kill the coordinator mid-round with the restart edge disabled: the
    // children are stranded awaiting acks from a dead coordinator.
    let mutant = ShrinkModel::new(&[0, 1, 2, 3], &[0, 0, 1, 1], &[3])
        .with_kills(&[0], 1)
        .with_mutation(ShrinkMutation::SkipRestart);
    let rep = explore(&mutant, Reduction::Exhaustive, &Budget::smoke());
    let cex = rep.counterexample.expect("skipping restarts must break convergence");
    let Violation::Protocol { detail } = &cex.violation else {
        panic!("expected a protocol violation, got {}", cex.violation)
    };
    assert!(detail.contains("converge"), "non-convergence is named: {detail}");
    assert!(replay(&mutant, &cex.trace).is_some(), "the trace must reproduce");
}

// ---- mutation: wrong election rule -------------------------------------

fn elect_last(e: &Reelection<'_>) -> usize {
    e.survivors_world.len() - 1
}

#[test]
fn wrong_election_rule_is_caught_at_a_terminal() {
    // With survivors {0,1,2,4} the highest survivor (comm rank 3, world
    // 4) coincides with the correct choice — the mutant is only wrong
    // once rank 4 also dies and the fallback (lowest survivor) applies.
    // The explorer must find that terminal.
    let mutant = ShrinkModel::new(&[0, 1, 2, 3, 4], &[0, 0, 0, 1, 1], &[3])
        .with_root(3)
        .with_kills(&[4], 1)
        .with_elect(elect_last);
    let rep = explore(&mutant, Reduction::Exhaustive, &Budget::smoke());
    let cex = rep.counterexample.expect("the wrong election rule must be caught");
    let Violation::Protocol { detail } = &cex.violation else {
        panic!("expected a protocol violation, got {}", cex.violation)
    };
    assert!(detail.contains("re-election"), "the election check fired: {detail}");
    assert!(
        cex.steps.iter().any(|s| s.contains("dies")),
        "the trace shows the death that exposes the bug: {:?}",
        cex.steps
    );
}

// ---- live-session export -----------------------------------------------

#[test]
fn exported_shrink_model_from_a_live_session_explores_clean() {
    // Kill node 1's leader on a [2, 2] cluster, let detection register
    // the death, export the agreement the survivors are about to run as
    // a protocol model, then actually run the shrink. The exported model
    // must explore clean — the implementation and the model see the same
    // members, nodes, and registered deaths (same scope keys).
    const VICTIM: usize = 2;
    let plan = FaultPlan::seeded(0xD1E).with_dead(VICTIM, 0.0).with_detect_bound_us(2_000);
    let cluster = SimCluster::new(spec(&[2, 2]).with_faults(plan));
    let report = cluster.run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ar = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            64,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        if env.rank_dead() {
            return None;
        }
        let operand = vec![w.rank() as u8; 64];
        ar.start_allreduce(env, &operand);
        let err = ar.try_wait(env).expect_err("a dead leader must surface, not hang");
        assert_eq!(err.world_rank, VICTIM);
        let model = ctx.export_shrink_model(env);
        let ctx = ctx.shrink(env);
        ar.rebuild(env, &ctx);
        env.barrier(ctx.parent());
        ar.free(env);
        Some(model)
    });
    let model = report
        .outputs
        .into_iter()
        .flatten()
        .next()
        .expect("a survivor exports the model");
    let r = explore(&model, Reduction::Exhaustive, &Budget::smoke());
    assert!(r.complete, "live-session shrink model must finish in budget");
    assert!(r.counterexample.is_none(), "{}", r.counterexample.unwrap());
    assert!(r.terminals >= 1);
}
