//! Message-fabric tests: matching semantics of the sharded lock-free
//! mailbox (DESIGN.md §5c) against the MPI point-to-point rules and
//! against the legacy mutex+condvar fabric.
//!
//! - scripted (deterministic) post/recv sequences must produce the exact
//!   same matches on both fabrics, including `MPI_ANY_SOURCE` picks;
//! - `MPI_ANY_SOURCE` is FIFO per source and non-overtaking;
//! - tag/communicator selectivity holds across sources that collide in
//!   one lane;
//! - a randomized concurrent multi-sender stress (via `util::quickprop`)
//!   preserves per-source FIFO and loses nothing on either fabric;
//! - cluster-level runs produce bit-identical results and virtual times
//!   on both fabrics — the fabric is a wall-clock optimization only.

use hympi::coll::{Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::SyncScheme;
use hympi::mpi::env::ProcEnv;
use hympi::mpi::msg::{Mailbox, Matcher, Msg, LANES};
use hympi::mpi::{Datatype, Payload, ReduceOp};
use hympi::util::quickprop;
use hympi::util::{to_bytes, Rng};
use std::sync::Arc;

fn msg(src: usize, tag: i64, comm: u64, bytes: &[u8]) -> Msg {
    Msg { src, tag, comm, sent_at: 0.0, data: Payload::from_vec(bytes.to_vec()) }
}

/// Run one scripted scenario on a mailbox: post everything in order, then
/// execute the receive script; returns the matched (src, first byte) per
/// receive.
fn run_script(mb: &Mailbox, posts: &[(usize, i64, u64, u8)], recvs: &[Matcher]) -> Vec<(usize, u8)> {
    for &(src, tag, comm, byte) in posts {
        mb.post(msg(src, tag, comm, &[byte]));
    }
    recvs
        .iter()
        .map(|m| {
            let got = mb.recv(*m);
            (got.src, got.data[0])
        })
        .collect()
}

#[test]
fn scripted_sequences_agree_between_fabrics() {
    // Deterministic random scripts: single-threaded posts give a total
    // arrival order, so both fabrics must make the *same* matching
    // decisions — including every ANY_SOURCE pick.
    quickprop::run(
        "fabric-script-parity",
        48,
        |r: &mut Rng| {
            let n = 4 + r.below(60);
            let posts: Vec<(usize, i64, u64, u8)> = (0..n)
                .map(|i| (r.below(2 * LANES + 3), 1 + r.below(3) as i64, r.below(2) as u64, i as u8))
                .collect();
            posts
        },
        |posts| {
            // Receive script, two phases so it can never block: first a
            // matched-source recv per even-indexed post (consumes, per
            // (src, tag, comm) triple, exactly a prefix count of what was
            // posted), then an ANY_SOURCE recv per odd-indexed post's
            // (tag, comm) class — counts match what remains exactly.
            let mut recvs = Vec::new();
            for (i, &(src, tag, comm, _)) in posts.iter().enumerate() {
                if i % 2 == 0 {
                    recvs.push(Matcher { src: Some(src), tag, comm });
                }
            }
            for (i, &(_, tag, comm, _)) in posts.iter().enumerate() {
                if i % 2 != 0 {
                    recvs.push(Matcher { src: None, tag, comm });
                }
            }
            let new = run_script(&Mailbox::new(), posts, &recvs);
            let old = run_script(&Mailbox::legacy(), posts, &recvs);
            if new != old {
                return Err(format!("fabrics diverged:\n  new: {new:?}\n  old: {old:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn any_source_is_fifo_per_source_and_non_overtaking() {
    for mb in [Mailbox::new(), Mailbox::legacy()] {
        // Interleave three sources, two of which share a lane.
        let (a, b, c) = (1usize, 1 + LANES, 2);
        for i in 0..10u8 {
            mb.post(msg(a, 7, 0, &[i]));
            mb.post(msg(b, 7, 0, &[100 + i]));
            mb.post(msg(c, 7, 0, &[200 + i]));
        }
        let mut seen = [0u8, 100, 200];
        for _ in 0..30 {
            let got = mb.recv(Matcher { src: None, tag: 7, comm: 0 });
            let which = match got.src {
                s if s == a => 0,
                s if s == b => 1,
                _ => 2,
            };
            assert_eq!(got.data[0], seen[which], "source {} overtaken", got.src);
            seen[which] += 1;
        }
        assert_eq!(mb.depth(), 0);
    }
}

#[test]
fn tag_and_comm_selectivity_across_colliding_lanes() {
    for mb in [Mailbox::new(), Mailbox::legacy()] {
        let (a, b) = (3usize, 3 + LANES); // same lane
        mb.post(msg(a, 1, 0, &[1]));
        mb.post(msg(b, 1, 0, &[2]));
        mb.post(msg(a, 2, 0, &[3]));
        mb.post(msg(b, 1, 5, &[4]));
        // Matched source skips the other source's identical (tag, comm).
        assert_eq!(mb.recv(Matcher { src: Some(b), tag: 1, comm: 0 }).data[0], 2);
        // Tag selects within a source.
        assert_eq!(mb.recv(Matcher { src: Some(a), tag: 2, comm: 0 }).data[0], 3);
        // Communicator selects across sources under ANY_SOURCE.
        assert_eq!(mb.recv(Matcher { src: None, tag: 1, comm: 5 }).data[0], 4);
        assert_eq!(mb.recv(Matcher { src: None, tag: 1, comm: 0 }).data[0], 1);
        assert_eq!(mb.depth(), 0);
    }
}

/// Concurrent stress: `senders` threads each post `per_sender` messages
/// (tags cycling over a small set) into one mailbox while the owner
/// drains it with a mix of matched and ANY_SOURCE receives. Checked on
/// both fabrics: nothing lost, nothing duplicated, per-source streams in
/// order for every tag.
fn stress(legacy: bool, senders: usize, per_sender: usize, tags: usize) -> Result<(), String> {
    let mb = Arc::new(Mailbox::with_mode(legacy));
    let handles: Vec<_> = (0..senders)
        .map(|s| {
            let mb = mb.clone();
            std::thread::spawn(move || {
                // Sources deliberately collide: sender s posts as source
                // s, so lanes are shared whenever senders > LANES — and
                // seq rides in the payload.
                for i in 0..per_sender {
                    let tag = 1 + (i % tags) as i64;
                    let bytes = (i as u32).to_le_bytes();
                    mb.post(msg(s, tag, 0, &bytes));
                }
            })
        })
        .collect();
    // Owner, phase 1: matched receives for the first half of every
    // sender's stream, in posting order — per-stream FIFO must deliver
    // exactly sequence i for the i-th receive.
    let half = per_sender / 2;
    for s in 0..senders {
        for i in 0..half {
            let tag = 1 + (i % tags) as i64;
            let got = mb.recv(Matcher { src: Some(s), tag, comm: 0 });
            let seq = u32::from_le_bytes(got.data[..4].try_into().unwrap());
            if seq != i as u32 {
                return Err(format!("matched overtaking: src {s} tag {tag} got seq {seq}, want {i}"));
            }
        }
    }
    // Phase 2: the remainder via ANY_SOURCE, per tag class with exactly
    // matching counts; per-(src, tag) streams must still be in order.
    let mut next_by_stream = vec![vec![u32::MAX; tags]; senders];
    for i in half..per_sender {
        let tag = 1 + (i % tags) as i64;
        for _ in 0..senders {
            let got = mb.recv(Matcher { src: None, tag, comm: 0 });
            let seq = u32::from_le_bytes(got.data[..4].try_into().unwrap());
            let t = (got.tag - 1) as usize;
            let want = next_by_stream[got.src][t];
            if want != u32::MAX && seq != want {
                return Err(format!(
                    "any-source overtaking: src {} tag {} got seq {seq}, want {want}",
                    got.src, got.tag
                ));
            }
            if want == u32::MAX && (seq as usize) < half {
                return Err(format!("duplicate: src {} tag {} seq {seq} seen twice", got.src, got.tag));
            }
            next_by_stream[got.src][t] = seq + tags as u32;
        }
    }
    for h in handles {
        h.join().map_err(|_| "sender panicked".to_string())?;
    }
    if mb.depth() != 0 {
        return Err(format!("{} messages stranded", mb.depth()));
    }
    Ok(())
}

#[test]
fn randomized_multi_sender_interleaving_stress() {
    quickprop::run(
        "fabric-concurrent-stress",
        12,
        |r: &mut Rng| {
            let senders = 2 + r.below(2 * LANES); // up to 2 per lane
            let per_sender = 20 + r.below(100);
            let tags = 1 + r.below(3);
            (senders, per_sender, tags)
        },
        |&(senders, per_sender, tags)| {
            stress(false, senders, per_sender, tags)?;
            stress(true, senders, per_sender, tags)
        },
    );
}

// ---------------------------------------------------------------------
// Cluster-level parity: the fabric must change wall clock only.
// ---------------------------------------------------------------------

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Full-op workload returning (result bytes, final virtual clock).
fn parity_workload(env: &mut ProcEnv) -> (Vec<u8>, f64) {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let mut cache = PlanCache::new();
    let fl = Flavor::hybrid(SyncScheme::Spin);
    let mut digest = Vec::new();
    for it in 0..3usize {
        let mine = vec![(me + it) as u8; 512];
        let mut ag = vec![0u8; 512 * p];
        cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut ag));
        let mut hy = vec![0u8; 512 * p];
        cache.allgather(env, &w, fl, &mine, Some(&mut hy));
        assert_eq!(ag, hy);
        digest.extend_from_slice(&ag);

        let vals: Vec<f64> = (0..64).map(|i| ((me + 1) * (i + it + 1)) as f64).collect();
        let mut ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut ar);
        digest.extend_from_slice(&ar);

        let mut bc = vec![it as u8; 1024];
        cache.bcast(env, &w, Flavor::Pure, 0, 1024, Some(&mut bc));
        digest.extend_from_slice(&bc);

        let full: Vec<f64> = (0..16 * p).map(|e| ((me + 1) * (e + 1)) as f64).collect();
        let mut rs = vec![0u8; 16 * 8];
        cache.reduce_scatter(env, &w, fl, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut rs);
        digest.extend_from_slice(&rs);
    }
    env.barrier(&w);
    let v = env.vclock();
    cache.free(env);
    (digest, v)
}

#[test]
fn new_and_legacy_fabric_agree_bitwise_and_in_virtual_time() {
    let new = SimCluster::new(spec(&[5, 3])).run(parity_workload);
    let old = SimCluster::new(spec(&[5, 3]).with_legacy_fabric(true)).run(parity_workload);
    assert_eq!(new.outputs.len(), old.outputs.len());
    for (r, ((da, va), (db, vb))) in new.outputs.iter().zip(old.outputs.iter()).enumerate() {
        assert_eq!(da, db, "rank {r}: results must not depend on the fabric");
        assert!(
            (va - vb).abs() < 1e-9,
            "rank {r}: modeled virtual time must not depend on the fabric ({va} vs {vb})"
        );
    }
    // Same number of modeled messages/bytes moved on both fabrics.
    assert_eq!(new.msgs, old.msgs);
    assert_eq!(new.bytes, old.bytes);
}

#[test]
fn fabric_handles_oversubscribed_barrier_storms() {
    // Many ranks, many barriers: exercises the doorbell park path and the
    // SyncGroup sleeper list under heavy oversubscription.
    let report = SimCluster::new(ClusterSpec::preset(Preset::VulcanHsw, 4)).run(|env| {
        let w = env.world();
        for _ in 0..5 {
            env.barrier(&w);
        }
        env.vclock()
    });
    assert_eq!(report.outputs.len(), 96);
    let v0 = report.vtimes[0];
    for v in &report.vtimes {
        assert!((v - v0).abs() < 1e-9, "barrier must align clocks");
    }
}
