//! Integration tests for the ISSUE-7 fault-injection layer and the
//! self-healing session surface:
//!
//! - **determinism** — the same [`FaultPlan`] seed yields bitwise-
//!   identical collective results *and* bit-equal per-rank virtual
//!   clocks on an irregular shape, for both §4.5 sync schemes at
//!   k ∈ {1, 2}; faults perturb timing, never bytes.
//! - **detection** — a rank dying mid-steady-state surfaces as
//!   `Err(RankFailed)` naming the victim on every survivor (blocking
//!   `try_wait` and polling `try_test` paths both), instead of hanging.
//! - **recovery** — survivors shrink the session, rebuild the handle,
//!   and the post-shrink hybrid result is bit-identical to a pure-MPI
//!   reference on the shrunken communicator; the rebuilt schedules pass
//!   the static verifier and cover exactly the survivor set. Killing a
//!   non-root leader (k = 1 and k = 2) and a non-leader child are both
//!   exercised, and [`PlanCache::purge_failed`] drops the doomed
//!   world-communicator plans on the way.

use hympi::analysis::{verify_survivors, RankSchedule};
use hympi::coll::{Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, SyncScheme};
use hympi::mpi::{Datatype, FaultPlan, ReduceOp};
use hympi::util::to_bytes;

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

const COUNT: usize = 512; // allreduce payload bytes (64 f64 elements)
const ITERS: usize = 6;

/// The chaos workload: persistent-handle allreduce rounds against fixed
/// modeled compute. Returns (result digest, final vclock) per rank —
/// the pair the determinism tests compare bit-for-bit.
fn chaos_workload(
    nodes: &'static [usize],
    plan: Option<FaultPlan>,
    scheme: SyncScheme,
    k: usize,
) -> (Vec<Vec<u8>>, Vec<f64>) {
    let s = match plan {
        Some(p) => spec(nodes).with_faults(p),
        None => spec(nodes),
    };
    let rep = SimCluster::new(s).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            scheme,
        );
        let vals: Vec<f64> = (0..COUNT / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        let mut digest = Vec::new();
        for _ in 0..ITERS {
            env.compute(50.0); // scaled by skew/straggler, noise ticks on top
            h.start_allreduce(env, &operand);
            h.wait(env);
            let view = h.result_view(COUNT).expect("hybrid handles are window-backed");
            digest.extend_from_slice(view);
        }
        env.barrier(&w);
        h.free(env);
        digest
    });
    (rep.outputs, rep.vtimes)
}

/// Same seed ⇒ bitwise-identical results and bit-equal vtimes; faults
/// never change result bytes relative to a clean run; noise strictly
/// stretches modeled time.
#[test]
fn same_seed_is_bitwise_reproducible() {
    let nodes: &'static [usize] = &[5, 3]; // irregular
    let plan = || {
        FaultPlan::seeded(0xDE7E_C7)
            .with_skew(0.25)
            .with_noise(100.0, 10.0)
            .with_straggler(3, 4.0)
    };
    for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
        for k in [1usize, 2] {
            let (clean_out, clean_vt) = chaos_workload(nodes, None, scheme, k);
            let (a_out, a_vt) = chaos_workload(nodes, Some(plan()), scheme, k);
            let (b_out, b_vt) = chaos_workload(nodes, Some(plan()), scheme, k);
            assert_eq!(a_out, b_out, "{scheme:?} k{k}: same seed, same bytes");
            for (r, (va, vb)) in a_vt.iter().zip(b_vt.iter()).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{scheme:?} k{k}: rank {r} vtime must be bit-equal across runs"
                );
            }
            assert_eq!(a_out, clean_out, "{scheme:?} k{k}: faults must never change results");
            let clean_max = clean_vt.iter().copied().fold(0.0, f64::max);
            let fault_max = a_vt.iter().copied().fold(0.0, f64::max);
            assert!(
                fault_max > clean_max,
                "{scheme:?} k{k}: skew+noise+straggler must stretch modeled time \
                 ({fault_max} vs clean {clean_max})"
            );
        }
    }
}

/// Run the kill scenario without recovery: every survivor must get
/// `Err(RankFailed)` naming the victim from the blocking wait path.
fn no_hang_case(nodes: &'static [usize], victim: usize, k: usize) {
    let plan = FaultPlan::seeded(7).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let operand = vec![w.rank() as u8; COUNT];
        let mut it = 0usize;
        while it < ITERS {
            if it >= 2 && env.rank_dead() {
                return None; // the victim stops participating here
            }
            h.start_allreduce(env, &operand);
            match h.try_wait(env) {
                Ok(_) => it += 1,
                Err(e) => return Some(e.world_rank),
            }
        }
        panic!("rank {}: the kill at iteration 2 must abort the loop", w.rank());
    });
    for (r, out) in rep.outputs.iter().enumerate() {
        match out {
            None => assert_eq!(r, victim, "only the victim returns dead"),
            Some(named) => {
                assert_eq!(*named, victim, "rank {r} must name the victim in RankFailed");
            }
        }
    }
}

/// A dead non-root leader: its node's members starve at the red sync,
/// the peer node's leader dies inside the bridge recv (typed panic,
/// caught by the work stage) — everyone still gets the typed error.
#[test]
fn dead_leader_surfaces_rank_failed_on_every_survivor() {
    no_hang_case(&[5, 3], 5, 1);
}

/// A dead non-leader child: nobody's direct peer died — node-1 members
/// starve at the red sync and node 0's leader is stranded behind an
/// alive-but-stuck bridge peer, which only the cascade escape resolves.
#[test]
fn dead_child_surfaces_rank_failed_on_every_survivor() {
    no_hang_case(&[5, 3], 7, 1);
}

/// The polling completion path: `try_test` never parks, so detection
/// rides the handle-local deadline armed when a poll makes no progress
/// while the registry is non-empty.
#[test]
fn try_test_poll_path_detects_death() {
    let nodes: &'static [usize] = &[6]; // single node: pure Await/Yellow stalls
    let victim = 3usize;
    let plan = FaultPlan::seeded(11).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = ctx.allgather_init(env, 64, SyncScheme::Spin);
        let mine = vec![w.rank() as u8; 64];
        if env.rank_dead() {
            return None;
        }
        h.start_allgather(env, &mine);
        loop {
            match h.try_test(env) {
                Ok(true) => panic!("rank {}: cannot complete without the victim", w.rank()),
                Ok(false) => env.compute(5.0), // overlap compute between polls
                Err(e) => return Some(e.world_rank),
            }
        }
    });
    for (r, out) in rep.outputs.iter().enumerate() {
        match out {
            None => assert_eq!(r, victim),
            Some(named) => assert_eq!(*named, victim, "rank {r} must name the victim"),
        }
    }
}

/// Kill → detect → purge → shrink → rebuild → verify: the full recovery
/// path. Post-shrink hybrid allreduce must match a pure-MPI reference on
/// the shrunken communicator bit-for-bit, and the rebuilt schedules must
/// verify clean over exactly the survivor set.
fn shrink_case(nodes: &'static [usize], victim: usize, k: usize) {
    let world: usize = nodes.iter().sum();
    let plan = FaultPlan::seeded(23).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let mut ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let vals: Vec<f64> = (0..COUNT / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        // A cached pure plan on the doomed world communicator: the
        // recovery path must purge it.
        let mut cache = PlanCache::new();
        let contrib = vec![w.rank() as u8; 16];
        let mut ag = vec![0u8; 16 * w.size()];
        cache.allgather(env, &w, Flavor::Pure, &contrib, Some(&mut ag));
        let mut it = 0usize;
        let mut shrunk = false;
        while it < ITERS {
            if it >= 2 && env.rank_dead() {
                return None; // the victim dies at the iteration-2 boundary
            }
            h.start_allreduce(env, &operand);
            match h.try_wait(env) {
                Ok(_) => it += 1,
                Err(e) => {
                    assert!(!shrunk, "one death, one recovery");
                    assert_eq!(e.world_rank, victim);
                    assert!(
                        cache.purge_failed(env) >= 1,
                        "the world-communicator plan must be purged"
                    );
                    ctx = ctx.shrink(env);
                    h.rebuild(env, &ctx);
                    shrunk = true;
                    // retry the same iteration on the shrunken session
                }
            }
        }
        assert!(shrunk, "rank {}: the kill must have been observed", w.rank());
        // Post-shrink parity: the handle's result vs a pure-MPI
        // allreduce on the shrunken communicator, bit-for-bit.
        let hy = h.result_view(COUNT).expect("window-backed").to_vec();
        let mut pure = operand.clone();
        cache.allreduce(env, ctx.parent(), Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut pure);
        assert_eq!(hy, pure, "post-shrink hybrid result must match pure MPI on the survivors");
        let sched = h.export_schedule(0);
        env.barrier(ctx.parent());
        h.free(env);
        cache.free(env);
        Some(sched)
    });
    let set: Vec<RankSchedule> = rep.outputs.into_iter().flatten().collect();
    assert_eq!(set.len(), world - 1, "every survivor exports a rebuilt schedule");
    let survivors: Vec<usize> = (0..world - 1).collect(); // shrunken-comm numbering
    let diags = verify_survivors(&set, &survivors);
    assert!(diags.is_empty(), "rebuilt schedules must verify clean, got: {diags:?}");
}

/// Kill the last node's primary leader at k = 1: the shrink rebuilds the
/// leader set and both bridge endpoints.
#[test]
fn shrink_after_dead_leader_k1() {
    shrink_case(&[5, 3], 5, 1);
}

/// Kill the same leader at k = 2: the surviving secondary leader and the
/// remaining child re-form a two-leader node.
#[test]
fn shrink_after_dead_leader_k2() {
    shrink_case(&[5, 3], 5, 2);
}

/// Kill a non-leader child: no bridge endpoint dies, detection rides the
/// red-sync starvation and the cascade escape, and the shrink drops one
/// window slot.
#[test]
fn shrink_after_dead_child_k1() {
    shrink_case(&[5, 3], 7, 1);
}
