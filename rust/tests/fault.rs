//! Integration tests for the ISSUE-7 fault-injection layer and the
//! self-healing session surface:
//!
//! - **determinism** — the same [`FaultPlan`] seed yields bitwise-
//!   identical collective results *and* bit-equal per-rank virtual
//!   clocks on an irregular shape, for both §4.5 sync schemes at
//!   k ∈ {1, 2}; faults perturb timing, never bytes.
//! - **detection** — a rank dying mid-steady-state surfaces as
//!   `Err(RankFailed)` naming the victim on every survivor (blocking
//!   `try_wait` and polling `try_test` paths both), instead of hanging.
//! - **recovery** — survivors shrink the session, rebuild the handle,
//!   and the post-shrink hybrid result is bit-identical to a pure-MPI
//!   reference on the shrunken communicator; the rebuilt schedules pass
//!   the static verifier and cover exactly the survivor set. Killing a
//!   non-root leader (k = 1 and k = 2) and a non-leader child are both
//!   exercised, and [`PlanCache::purge_failed`] drops the doomed
//!   world-communicator plans on the way.

use hympi::analysis::{verify_survivors, RankSchedule};
use hympi::coll::{Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{
    AllreduceMethod, HybridCtx, LeaderPolicy, Resilience, RetryPolicy, RootPolicy, SyncScheme,
};
use hympi::mpi::{Datatype, FaultPlan, ReduceOp};
use hympi::util::to_bytes;

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

const COUNT: usize = 512; // allreduce payload bytes (64 f64 elements)
const ITERS: usize = 6;

/// The chaos workload: persistent-handle allreduce rounds against fixed
/// modeled compute. Returns (result digest, final vclock) per rank —
/// the pair the determinism tests compare bit-for-bit.
fn chaos_workload(
    nodes: &'static [usize],
    plan: Option<FaultPlan>,
    scheme: SyncScheme,
    k: usize,
) -> (Vec<Vec<u8>>, Vec<f64>) {
    let s = match plan {
        Some(p) => spec(nodes).with_faults(p),
        None => spec(nodes),
    };
    let rep = SimCluster::new(s).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            scheme,
        );
        let vals: Vec<f64> = (0..COUNT / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        let mut digest = Vec::new();
        for _ in 0..ITERS {
            env.compute(50.0); // scaled by skew/straggler, noise ticks on top
            h.start_allreduce(env, &operand);
            h.wait(env);
            let view = h.result_view(COUNT).expect("hybrid handles are window-backed");
            digest.extend_from_slice(view);
        }
        env.barrier(&w);
        h.free(env);
        digest
    });
    (rep.outputs, rep.vtimes)
}

/// Same seed ⇒ bitwise-identical results and bit-equal vtimes; faults
/// never change result bytes relative to a clean run; noise strictly
/// stretches modeled time.
#[test]
fn same_seed_is_bitwise_reproducible() {
    let nodes: &'static [usize] = &[5, 3]; // irregular
    let plan = || {
        FaultPlan::seeded(0xDE7E_C7)
            .with_skew(0.25)
            .with_noise(100.0, 10.0)
            .with_straggler(3, 4.0)
    };
    for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
        for k in [1usize, 2] {
            let (clean_out, clean_vt) = chaos_workload(nodes, None, scheme, k);
            let (a_out, a_vt) = chaos_workload(nodes, Some(plan()), scheme, k);
            let (b_out, b_vt) = chaos_workload(nodes, Some(plan()), scheme, k);
            assert_eq!(a_out, b_out, "{scheme:?} k{k}: same seed, same bytes");
            for (r, (va, vb)) in a_vt.iter().zip(b_vt.iter()).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{scheme:?} k{k}: rank {r} vtime must be bit-equal across runs"
                );
            }
            assert_eq!(a_out, clean_out, "{scheme:?} k{k}: faults must never change results");
            let clean_max = clean_vt.iter().copied().fold(0.0, f64::max);
            let fault_max = a_vt.iter().copied().fold(0.0, f64::max);
            assert!(
                fault_max > clean_max,
                "{scheme:?} k{k}: skew+noise+straggler must stretch modeled time \
                 ({fault_max} vs clean {clean_max})"
            );
        }
    }
}

/// Run the kill scenario without recovery: every survivor must get
/// `Err(RankFailed)` naming the victim from the blocking wait path.
fn no_hang_case(nodes: &'static [usize], victim: usize, k: usize) {
    let plan = FaultPlan::seeded(7).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let operand = vec![w.rank() as u8; COUNT];
        let mut it = 0usize;
        while it < ITERS {
            if it >= 2 && env.rank_dead() {
                return None; // the victim stops participating here
            }
            h.start_allreduce(env, &operand);
            match h.try_wait(env) {
                Ok(_) => it += 1,
                Err(e) => return Some(e.world_rank),
            }
        }
        panic!("rank {}: the kill at iteration 2 must abort the loop", w.rank());
    });
    for (r, out) in rep.outputs.iter().enumerate() {
        match out {
            None => assert_eq!(r, victim, "only the victim returns dead"),
            Some(named) => {
                assert_eq!(*named, victim, "rank {r} must name the victim in RankFailed");
            }
        }
    }
}

/// A dead non-root leader: its node's members starve at the red sync,
/// the peer node's leader dies inside the bridge recv (typed panic,
/// caught by the work stage) — everyone still gets the typed error.
#[test]
fn dead_leader_surfaces_rank_failed_on_every_survivor() {
    no_hang_case(&[5, 3], 5, 1);
}

/// A dead non-leader child: nobody's direct peer died — node-1 members
/// starve at the red sync and node 0's leader is stranded behind an
/// alive-but-stuck bridge peer, which only the cascade escape resolves.
#[test]
fn dead_child_surfaces_rank_failed_on_every_survivor() {
    no_hang_case(&[5, 3], 7, 1);
}

/// The polling completion path: `try_test` never parks, so detection
/// rides the handle-local deadline armed when a poll makes no progress
/// while the registry is non-empty.
#[test]
fn try_test_poll_path_detects_death() {
    let nodes: &'static [usize] = &[6]; // single node: pure Await/Yellow stalls
    let victim = 3usize;
    let plan = FaultPlan::seeded(11).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = ctx.allgather_init(env, 64, SyncScheme::Spin);
        let mine = vec![w.rank() as u8; 64];
        if env.rank_dead() {
            return None;
        }
        h.start_allgather(env, &mine);
        loop {
            match h.try_test(env) {
                Ok(true) => panic!("rank {}: cannot complete without the victim", w.rank()),
                Ok(false) => env.compute(5.0), // overlap compute between polls
                Err(e) => return Some(e.world_rank),
            }
        }
    });
    for (r, out) in rep.outputs.iter().enumerate() {
        match out {
            None => assert_eq!(r, victim),
            Some(named) => assert_eq!(*named, victim, "rank {r} must name the victim"),
        }
    }
}

/// Kill → detect → purge → shrink → rebuild → verify: the full recovery
/// path. Post-shrink hybrid allreduce must match a pure-MPI reference on
/// the shrunken communicator bit-for-bit, and the rebuilt schedules must
/// verify clean over exactly the survivor set.
fn shrink_case(nodes: &'static [usize], victim: usize, k: usize) {
    let world: usize = nodes.iter().sum();
    let plan = FaultPlan::seeded(23).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let mut ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let vals: Vec<f64> = (0..COUNT / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        // A cached pure plan on the doomed world communicator: the
        // recovery path must purge it.
        let mut cache = PlanCache::new();
        let contrib = vec![w.rank() as u8; 16];
        let mut ag = vec![0u8; 16 * w.size()];
        cache.allgather(env, &w, Flavor::Pure, &contrib, Some(&mut ag));
        let mut it = 0usize;
        let mut shrunk = false;
        while it < ITERS {
            if it >= 2 && env.rank_dead() {
                return None; // the victim dies at the iteration-2 boundary
            }
            h.start_allreduce(env, &operand);
            match h.try_wait(env) {
                Ok(_) => it += 1,
                Err(e) => {
                    assert!(!shrunk, "one death, one recovery");
                    assert_eq!(e.world_rank, victim);
                    assert!(
                        cache.purge_failed(env) >= 1,
                        "the world-communicator plan must be purged"
                    );
                    ctx = ctx.shrink(env);
                    h.rebuild(env, &ctx);
                    shrunk = true;
                    // retry the same iteration on the shrunken session
                }
            }
        }
        assert!(shrunk, "rank {}: the kill must have been observed", w.rank());
        // Post-shrink parity: the handle's result vs a pure-MPI
        // allreduce on the shrunken communicator, bit-for-bit.
        let hy = h.result_view(COUNT).expect("window-backed").to_vec();
        let mut pure = operand.clone();
        cache.allreduce(env, ctx.parent(), Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut pure);
        assert_eq!(hy, pure, "post-shrink hybrid result must match pure MPI on the survivors");
        let sched = h.export_schedule(0);
        env.barrier(ctx.parent());
        h.free(env);
        cache.free(env);
        Some(sched)
    });
    let set: Vec<RankSchedule> = rep.outputs.into_iter().flatten().collect();
    assert_eq!(set.len(), world - 1, "every survivor exports a rebuilt schedule");
    let survivors: Vec<usize> = (0..world - 1).collect(); // shrunken-comm numbering
    let diags = verify_survivors(&set, &survivors);
    assert!(diags.is_empty(), "rebuilt schedules must verify clean, got: {diags:?}");
}

/// Kill the last node's primary leader at k = 1: the shrink rebuilds the
/// leader set and both bridge endpoints.
#[test]
fn shrink_after_dead_leader_k1() {
    shrink_case(&[5, 3], 5, 1);
}

/// Kill the same leader at k = 2: the surviving secondary leader and the
/// remaining child re-form a two-leader node.
#[test]
fn shrink_after_dead_leader_k2() {
    shrink_case(&[5, 3], 5, 2);
}

/// Kill a non-leader child: no bridge endpoint dies, detection rides the
/// red-sync starvation and the cascade escape, and the shrink drops one
/// window slot.
#[test]
fn shrink_after_dead_child_k1() {
    shrink_case(&[5, 3], 7, 1);
}

// ---- ISSUE 8: epoch-tagged agreement + run_resilient -----------------------

/// Virtual µs charged per modeled detection round in the recovery tests:
/// large enough to dominate the compute/collective vtime, which is what
/// lets the vtime-scheduled deaths below land at specific driver
/// checkpoints (shrink-loop top, pre-rebuild) with wide margins.
const DETECT_COST: f64 = 5_000.0;

fn recovery_plan(seed: u64) -> FaultPlan {
    FaultPlan::seeded(seed).with_detect_bound_us(2_000).with_detect_cost_us(DETECT_COST)
}

/// Generic [`HybridCtx::run_resilient`] drill: persistent allreduce
/// rounds with per-rank death guards (`die_at`: world rank → iteration
/// threshold for the cooperative in-attempt checkpoint; vtime-only
/// deaths in the plan fire at the driver's own checkpoints instead).
/// Returns per rank `None` if the rank died, else
/// `Some((final comm size, recovery epochs, total detection vtime))`
/// after asserting the resilient result is bit-identical to pure MPI on
/// the final survivor set.
fn resilient_case(
    nodes: &'static [usize],
    plan: FaultPlan,
    die_at: &'static [(usize, usize)],
    iters: usize,
) -> Vec<Option<(usize, usize, f64)>> {
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            COUNT,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let vals: Vec<f64> = (0..COUNT / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        // A cached pure plan on the doomed world communicator: the
        // driver's purge step must be able to drop it.
        let mut cache = PlanCache::new();
        let contrib = vec![w.rank() as u8; 16];
        let mut ag = vec![0u8; 16 * w.size()];
        cache.allgather(env, &w, Flavor::Pure, &contrib, Some(&mut ag));
        let me = env.world_rank();
        let my_die = die_at.iter().find(|&&(r, _)| r == me).map(|&(_, at)| at);
        let mut it = 0usize;
        let res = ctx.run_resilient(
            env,
            &mut [&mut h],
            Some(&mut cache),
            RetryPolicy::default(),
            |env, _cx, hs| {
                let h = &mut *hs[0];
                while it < iters {
                    if let Some(at) = my_die {
                        if it >= at && env.rank_dead() {
                            return Ok(None);
                        }
                    }
                    h.start_ok(env)?;
                    h.start_allreduce(env, &operand);
                    h.try_wait(env)?;
                    it += 1;
                }
                Ok(Some(h.result_view(COUNT).expect("window-backed").to_vec()))
            },
        );
        match res {
            Resilience::Completed { value, ctx: fin, epochs } => {
                let mut pure = operand.clone();
                cache.allreduce(
                    env,
                    fin.parent(),
                    Flavor::Pure,
                    Datatype::F64,
                    ReduceOp::Sum,
                    &mut pure,
                );
                assert_eq!(
                    value, pure,
                    "rank {me}: resilient result must match pure MPI on the final survivor set"
                );
                let detect: f64 = epochs.iter().map(|e| e.detect_us).sum();
                Some((fin.parent().size(), epochs.len(), detect))
            }
            Resilience::Died => None,
            Resilience::Exhausted { last, epochs } => {
                panic!("rank {me}: retries exhausted after {} epochs: {last}", epochs.len())
            }
        }
    });
    rep.outputs
}

fn assert_survivor(out: &[Option<(usize, usize, f64)>], rank: usize, size: usize) {
    let (got_size, epochs, detect) =
        out[rank].unwrap_or_else(|| panic!("rank {rank} must survive and complete"));
    assert_eq!(got_size, size, "rank {rank}: final communicator size");
    assert!(epochs >= 1, "rank {rank}: at least one recovery epoch must have run");
    assert!(detect > 0.0, "rank {rank}: detection vtime must be charged");
}

/// The shrink coordinator (lowest survivor, rank 0) dies *during* the
/// recovery — it observes its own death at the driver's shrink-loop
/// checkpoint, so from every other survivor's view the coordinator goes
/// silent mid-agreement. The bounded parks expire, the registry change
/// restarts the round under a higher epoch, and rank 1 — the
/// next-lowest survivor — coordinates the rest of the agreement.
#[test]
fn coordinator_death_mid_agreement_restarts_the_round() {
    // Rank 5 dies at iteration 2 (the failure everyone detects); rank 0
    // is scheduled to die at 2 500 vµs — past session setup, but well
    // before the ≥ 5 000 vµs detection charge every failing wait adds,
    // so its first post-failure checkpoint (shrink-loop top) retires it.
    let plan = recovery_plan(31).with_dead(5, 0.0).with_dead(0, 2_500.0);
    let out = resilient_case(&[5, 3], plan, &[(5, 2)], ITERS);
    assert!(out[5].is_none(), "rank 5 is a casualty");
    assert!(out[0].is_none(), "rank 0 (the coordinator) is a casualty");
    for r in [1, 2, 3, 4, 6, 7] {
        assert_survivor(&out, r, 6);
    }
}

/// Two ranks die in the same detection window (one recovery epoch's
/// worth of wall time): whichever death the first agreement round
/// misses lands as a restart or as a failed rebuild, and the driver
/// converges to the 6-rank survivor set either way.
#[test]
fn overlapping_deaths_converge_to_final_survivor_set() {
    let plan = recovery_plan(37).with_dead(5, 0.0).with_dead(7, 0.0);
    let out = resilient_case(&[5, 3], plan, &[(5, 2), (7, 2)], ITERS);
    assert!(out[5].is_none() && out[7].is_none(), "both victims retire");
    for r in [0, 1, 2, 3, 4, 6] {
        assert_survivor(&out, r, 6);
    }
}

/// A death *during rebuild*: rank 4 completes the epoch-1 agreement and
/// the shrunken session's create, then dies at the driver's pre-rebuild
/// checkpoint. The survivors' handle re-inits strand on it, abandon via
/// their bounded parks (window-alloc deadline, cascade escape on the
/// bridge), and epoch 2 shrinks around it.
#[test]
fn death_during_rebuild_takes_a_second_epoch() {
    // 8 000 vµs sits between rank 4's shrink-loop checkpoint (≈ one
    // 5 000 vµs detection charge after the failing wait) and its
    // pre-rebuild checkpoint (a second 5 000 vµs charge inside shrink).
    let plan = recovery_plan(43).with_dead(5, 0.0).with_dead(4, 8_000.0);
    let out = resilient_case(&[5, 3], plan, &[(5, 2)], ITERS);
    assert!(out[5].is_none(), "rank 5 is the first casualty");
    assert!(out[4].is_none(), "rank 4 dies between shrink and rebuild");
    for r in [0, 1, 2, 3, 6, 7] {
        assert_survivor(&out, r, 6);
        let (_, epochs, _) = out[r].unwrap();
        assert!(epochs >= 2, "rank {r}: the rebuild-time death must cost a second epoch");
    }
}

/// Dead fixed root with [`RootPolicy::Reelect`]: rebuild re-elects the
/// lowest survivor on the dead root's former node (world rank 6 after
/// world rank 5 — node 1's leader — dies) for both a rooted bcast and a
/// rooted scatter, and the handles keep producing root-correct bytes.
#[test]
fn dead_root_is_reelected_for_bcast_and_scatter() {
    const ROOT_W: usize = 5; // node 1's leader on [5, 3]
    let nodes: &'static [usize] = &[5, 3];
    let plan = recovery_plan(41).with_dead(ROOT_W, 0.0);
    let rep = SimCluster::new(spec(nodes).with_faults(plan)).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut bc =
            ctx.bcast_init_split(env, COUNT, SyncScheme::Barrier, RootPolicy::reelect(ROOT_W), 1);
        let mut sc =
            ctx.scatter_init_split(env, 64, SyncScheme::Barrier, RootPolicy::reelect(ROOT_W), 1);
        let me = env.world_rank();
        let mut it = 0usize;
        let res = ctx.run_resilient(
            env,
            &mut [&mut bc, &mut sc],
            None,
            RetryPolicy::default(),
            |env, cx, hs| {
                while it < ITERS {
                    if me == ROOT_W && it >= 2 && env.rank_dead() {
                        return Ok(None);
                    }
                    let root = hs[0].root_policy().fixed_root().expect("rooted handle");
                    let root_w = cx.parent().world_of(root);
                    let bcast_bytes = vec![root_w as u8 + 1; COUNT];
                    {
                        let h = &mut *hs[0];
                        h.start_ok(env)?;
                        let data = (cx.parent().rank() == root).then_some(&bcast_bytes[..]);
                        h.start_bcast(env, root, data);
                        h.try_wait(env)?;
                        assert_eq!(
                            h.result_view(COUNT).expect("window-backed"),
                            &bcast_bytes[..],
                            "rank {me}: bcast bytes must come from the current root"
                        );
                    }
                    {
                        let h = &mut *hs[1];
                        h.start_ok(env)?;
                        let p = cx.parent().size();
                        let send: Option<Vec<u8>> = (cx.parent().rank() == root).then(|| {
                            (0..p).flat_map(|r| vec![(root_w * 16 + r) as u8; 64]).collect()
                        });
                        h.start_scatter(env, root, send.as_deref());
                        h.try_wait(env)?;
                        let mine = vec![(root_w * 16 + cx.parent().rank()) as u8; 64];
                        assert_eq!(
                            h.result_view(64).expect("window-backed"),
                            &mine[..],
                            "rank {me}: scatter chunk must come from the current root"
                        );
                    }
                    it += 1;
                }
                Ok(Some(hs[0].root_policy().fixed_root().expect("rooted handle")))
            },
        );
        match res {
            Resilience::Completed { value: root, ctx: fin, epochs } => {
                assert!(!epochs.is_empty(), "rank {me}: the root death must cost an epoch");
                Some(fin.parent().world_of(root))
            }
            Resilience::Died => None,
            Resilience::Exhausted { last, epochs } => {
                panic!("rank {me}: retries exhausted after {} epochs: {last}", epochs.len())
            }
        }
    });
    for (r, out) in rep.outputs.iter().enumerate() {
        match out {
            None => assert_eq!(r, ROOT_W, "only the dead root retires"),
            Some(root_w) => assert_eq!(
                *root_w, 6,
                "rank {r}: default re-election picks the lowest survivor on the root's node"
            ),
        }
    }
}

/// Seeded multi-epoch soak: three staggered deaths across eight rounds,
/// recovered epoch by epoch by `run_resilient`; the surviving five
/// ranks' final allreduce is asserted (inside [`resilient_case`])
/// bit-identical to pure MPI on the final survivor set.
#[test]
fn multi_epoch_soak_is_bitwise_correct_on_final_survivors() {
    let plan =
        recovery_plan(53).with_dead(5, 0.0).with_dead(7, 0.0).with_dead(2, 0.0).with_skew(0.25);
    let out = resilient_case(&[5, 3], plan, &[(5, 1), (7, 3), (2, 5)], 8);
    for v in [2, 5, 7] {
        assert!(out[v].is_none(), "victim {v} retires");
    }
    for r in [0, 1, 3, 4, 6] {
        assert_survivor(&out, r, 5);
    }
}
