//! Integration tests for the `HybridCtx` session API (ISSUE 4):
//! multi-leader (k = 1, 2, 4) hybrid collectives vs the pure-MPI
//! references, bit-exact on irregular node shapes under both §4.5 sync
//! schemes; persistent-handle reuse with zero re-setup cost; the k = 1
//! session's leader/bridge shape (the paper's `comm_package` layout);
//! and the multi-lane NIC acceptance bound (k = 2 strictly cheaper than
//! k = 1 on ≥256 KiB bridge blocks while k = 1 stays bit-identical to
//! the single-leader path).

use hympi::coll::{Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, SyncScheme};
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::{cast_slice, to_bytes};

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Deterministic rank-unique byte payload.
fn payload(r: usize, m: usize) -> Vec<u8> {
    (0..m).map(|i| (r.wrapping_mul(131) ^ i.wrapping_mul(29)) as u8).collect()
}

/// Every op, hybrid-at-k vs pure, one irregular cluster shape, one
/// scheme. Data is integer-valued f64 (or raw bytes), so every reduction
/// order is exact and the comparison is bit-for-bit.
fn check_all_ops(nodes: &'static [usize], k: usize, scheme: SyncScheme) {
    let report = SimCluster::new(spec(nodes)).run(move |env| {
        let w = env.world();
        let p = w.size();
        let me = w.rank();
        let mut cache = PlanCache::new();
        let fl = Flavor::hybrid_k(scheme, k);
        let n = 4usize; // doubles per rank/block

        // allgather --------------------------------------------------
        let mine: Vec<f64> = (0..n).map(|i| (me * n + i) as f64).collect();
        let mut pure = vec![0u8; n * 8 * p];
        cache.allgather(env, &w, Flavor::Pure, to_bytes(&mine), Some(&mut pure));
        let mut hy = vec![0u8; n * 8 * p];
        cache.allgather(env, &w, fl, to_bytes(&mine), Some(&mut hy));
        assert_eq!(pure, hy, "allgather {nodes:?} k {k} {scheme:?}");

        // bcast, rooted at a child on the last node -------------------
        let root = p - 1;
        let msg = payload(root, 100);
        let mut pure_bc = if me == root { msg.clone() } else { vec![0u8; 100] };
        cache.bcast(env, &w, Flavor::Pure, root, 100, Some(&mut pure_bc));
        let mut hy_bc = if me == root { msg.clone() } else { vec![0u8; 100] };
        cache.bcast(env, &w, fl, root, 100, Some(&mut hy_bc));
        assert_eq!(pure_bc, hy_bc, "bcast {nodes:?} k {k} {scheme:?}");

        // allreduce ---------------------------------------------------
        let vals: Vec<f64> = (0..n).map(|i| ((me + 1) * (i + 3)) as f64).collect();
        let mut pure_ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut pure_ar);
        let mut hy_ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut hy_ar);
        assert_eq!(pure_ar, hy_ar, "allreduce {nodes:?} k {k} {scheme:?}");

        // reduce_scatter ----------------------------------------------
        let full: Vec<f64> = (0..n * p).map(|e| ((me + 1) * (e + 1)) as f64).collect();
        let mut pure_rs = vec![0u8; n * 8];
        cache.reduce_scatter(
            env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut pure_rs,
        );
        let mut hy_rs = vec![0u8; n * 8];
        cache.reduce_scatter(env, &w, fl, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut hy_rs);
        assert_eq!(pure_rs, hy_rs, "reduce_scatter {nodes:?} k {k} {scheme:?}");

        // gather to a mid-cluster child -------------------------------
        let groot = p / 2;
        let blk = payload(me, 32);
        let mut pure_g = vec![0u8; 32 * p];
        let rb = (me == groot).then_some(&mut pure_g[..]);
        cache.gather(env, &w, Flavor::Pure, groot, &blk, rb);
        let mut hy_g = vec![0u8; 32 * p];
        let rb = (me == groot).then_some(&mut hy_g[..]);
        cache.gather(env, &w, fl, groot, &blk, rb);
        if me == groot {
            assert_eq!(pure_g, hy_g, "gather {nodes:?} k {k} {scheme:?}");
        }

        // scatter from the same root ----------------------------------
        let full_sc: Vec<u8> = (0..p).flat_map(|r| payload(r + 7, 32)).collect();
        let mut pure_sc = vec![0u8; 32];
        cache.scatter(env, &w, Flavor::Pure, groot, (me == groot).then_some(&full_sc[..]), &mut pure_sc);
        let mut hy_sc = vec![0u8; 32];
        cache.scatter(env, &w, fl, groot, (me == groot).then_some(&full_sc[..]), &mut hy_sc);
        assert_eq!(pure_sc, hy_sc, "scatter {nodes:?} k {k} {scheme:?}");
        assert_eq!(pure_sc, payload(me + 7, 32));

        env.barrier(&w);
        cache.free(env);
        cast_slice::<f64>(&pure_ar)
    });
    // Cross-rank agreement of the reduced vector.
    let first = &report.outputs[0];
    for got in &report.outputs {
        assert_eq!(got, first);
    }
}

#[test]
fn k1_matches_pure_on_irregular_shapes_both_schemes() {
    check_all_ops(&[5, 3, 4], 1, SyncScheme::Spin);
    check_all_ops(&[5, 3, 4], 1, SyncScheme::Barrier);
}

#[test]
fn k2_matches_pure_on_irregular_shapes_both_schemes() {
    check_all_ops(&[5, 3, 4], 2, SyncScheme::Spin);
    check_all_ops(&[5, 3, 4], 2, SyncScheme::Barrier);
    check_all_ops(&[3, 2, 2, 3], 2, SyncScheme::Spin); // clamps to k = 2
}

#[test]
fn k4_matches_pure_on_irregular_shapes_both_schemes() {
    // Smallest node hosts 4 ranks, so k = 4 runs unclamped.
    check_all_ops(&[5, 4, 7], 4, SyncScheme::Spin);
    check_all_ops(&[5, 4, 7], 4, SyncScheme::Barrier);
    // And a shape where k = 4 clamps (min population 3).
    check_all_ops(&[5, 3], 4, SyncScheme::Spin);
}

#[test]
fn persistent_handles_reuse_without_resetup() {
    // The MPI_Allreduce_init shape: one init, many start/wait cycles —
    // stable window identity, and identical steady-state virtual time
    // per invocation (nothing re-split, re-gathered or re-allocated).
    let report = SimCluster::new(spec(&[5, 3])).run(|env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(2));
        let mut ag = ctx.allgather_init(env, 256, SyncScheme::Spin);
        let mut ar = ctx.allreduce_init(
            env, Datatype::F64, ReduceOp::Sum, 64, AllreduceMethod::Tuned, SyncScheme::Spin,
        );
        let win0 = ag.window().map(|h| h.win.as_ref() as *const _ as usize).unwrap();
        let mine = vec![w.rank() as u8; 256];
        let vals = vec![(w.rank() + 1) as f64; 8];
        let mut dts = Vec::new();
        for _ in 0..4 {
            env.harness_sync(&w);
            let t0 = env.vclock();
            ag.start_allgather(env, &mine);
            ag.wait(env);
            ar.start_allreduce(env, to_bytes(&vals));
            ar.wait(env);
            dts.push(env.vclock() - t0);
        }
        let win1 = ag.window().map(|h| h.win.as_ref() as *const _ as usize).unwrap();
        env.barrier(ctx.shmem());
        ag.free(env);
        ar.free(env);
        (win0 == win1, dts)
    });
    for (stable, dts) in report.outputs {
        assert!(stable, "windows must survive across start/wait cycles");
        assert!(
            (dts[1] - dts[2]).abs() < 1e-9 && (dts[2] - dts[3]).abs() < 1e-9,
            "per-invocation vtime must be constant in steady state (zero re-setup): {dts:?}"
        );
    }
}

#[test]
fn k1_session_exposes_the_comm_package_shape() {
    // The paper's `comm_package` layout on a 5+3 cluster, expressed as a
    // k = 1 session: node-lowest ranks (world 0 and 5) are leaders and
    // sit on a 2-member bridge communicator; children get no bridge; the
    // shmem communicator spans exactly the local node; and a collective
    // through the session is readable from the shared window.
    let report = SimCluster::new(spec(&[5, 3])).run(|env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        assert_eq!(ctx.leaders_per_node(), 1);
        assert_eq!(ctx.nnodes(), 2);
        let on_first_node = me < 5;
        assert_eq!(ctx.node_index(), usize::from(!on_first_node));
        assert_eq!(ctx.shmem_size(), if on_first_node { 5 } else { 3 });
        assert_eq!(ctx.is_leader(), me == 0 || me == 5);
        match ctx.bridge() {
            Some(b) => {
                assert!(ctx.is_leader(), "only leaders may hold a bridge (rank {me})");
                assert_eq!(b.size(), 2);
                assert_eq!(b.rank(), usize::from(me == 5));
            }
            None => assert!(!ctx.is_leader(), "leaders must hold a bridge (rank {me})"),
        }

        // A collective through the session, read back from the window.
        let mine = payload(me, 48);
        let mut ag = ctx.allgather_init(env, 48, SyncScheme::Spin);
        ag.start_allgather(env, &mine);
        ag.wait(env);
        let all = ag.window().unwrap().load(env, 0, 48 * ctx.parent().size());
        env.barrier(ctx.shmem());
        ag.free(env);
        all
    });
    let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 48)).collect();
    for got in report.outputs {
        assert_eq!(got, expect, "gathered window contents must be the ordered rank payloads");
    }
}

#[test]
fn k2_strictly_below_k1_on_256kib_bridge_blocks_and_k1_unchanged() {
    // The PR-4 acceptance criterion. 16-rank VulcanSb nodes at
    // 16 KiB/rank make 256 KiB node blocks on the bridge.
    let msg = 16 * 1024;
    let vt_and_bytes = |k: usize| {
        let report = SimCluster::new(ClusterSpec::preset(Preset::VulcanSb, 2)).run(move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let mut ag = ctx.allgather_init(env, msg, SyncScheme::Spin);
            let mine = payload(w.rank() % 13, msg);
            env.harness_sync(&w);
            let t0 = env.vclock();
            ag.start_allgather(env, &mine);
            ag.wait(env);
            let dt = env.vclock() - t0;
            let digest = ag.window().unwrap().load(env, 0, msg * w.size());
            env.barrier(ctx.shmem());
            ag.free(env);
            (dt, digest)
        });
        let vt = report.outputs.iter().map(|(dt, _)| *dt).fold(0.0f64, f64::max);
        let bytes = report.outputs[0].1.clone();
        (vt, bytes)
    };
    let (vt1, bytes1) = vt_and_bytes(1);
    let (vt2, bytes2) = vt_and_bytes(2);
    let (vt4, bytes4) = vt_and_bytes(4);
    assert!(vt2 < vt1, "k=2 modeled vtime ({vt2}) must be strictly below k=1 ({vt1})");
    assert_eq!(bytes1, bytes2, "result bytes must not depend on k");
    assert_eq!(bytes1, bytes4, "result bytes must not depend on k");
    assert!(vt4 <= vt2 * 1.5, "k=4 must not regress catastrophically ({vt4} vs k=2 {vt2})");
}
