//! Cross-module integration tests: whole hybrid programs on irregular
//! clusters, backend parity through the PJRT runtime, and kernel apps
//! composing collectives + compute.

use hympi::coll;
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, SyncScheme};
use hympi::kernels::{self, Backend, Variant};
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::{cast_slice, to_bytes};

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// A full hybrid program exercising all three collectives back-to-back on
/// one session context — the composition pattern of a real application.
/// Runs at one and two leaders per node.
#[test]
fn hybrid_program_composes_all_three_collectives() {
    for k in [1usize, 2] {
        let report = SimCluster::new(spec(&[5, 3, 4])).run(move |env| {
            let w = env.world();
            let p = w.size();
            let me = w.rank();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));

            // allgather: every rank contributes 3 doubles.
            let msg = 24usize;
            let mut ag = ctx.allgather_init(env, msg, SyncScheme::Spin);
            let mine = [me as f64, 2.0 * me as f64, -1.0];
            ag.start_allgather(env, to_bytes(&mine));
            ag.wait(env);
            let gathered: Vec<f64> = cast_slice(&ag.window().unwrap().load(env, 0, msg * p));

            // bcast: rank 7 (a child) broadcasts a derived value.
            let mut bc = ctx.bcast_init(env, 8, SyncScheme::Spin);
            let root = 7usize;
            let payload = [gathered.iter().sum::<f64>()];
            let arg = (me == root).then(|| to_bytes(&payload));
            bc.start_bcast(env, root, arg.as_deref());
            bc.wait(env);
            let broadcasted = cast_slice::<f64>(&bc.window().unwrap().load(env, 0, 8))[0];

            // allreduce: max over ranks.
            let mut ar = ctx.allreduce_init(
                env, Datatype::F64, ReduceOp::Max, 8, AllreduceMethod::Tuned, SyncScheme::Spin,
            );
            ar.start_allreduce(env, to_bytes(&[me as f64]));
            let g = ar.wait(env);
            let reduced = cast_slice::<f64>(&ar.window().unwrap().load(env, g, 8))[0];

            env.barrier(ctx.shmem());
            ag.free(env);
            bc.free(env);
            ar.free(env);
            (gathered, broadcasted, reduced)
        });

        let p = 12;
        let expect_gather: Vec<f64> =
            (0..p).flat_map(|r| [r as f64, 2.0 * r as f64, -1.0]).collect();
        let expect_bcast: f64 = expect_gather.iter().sum();
        for (gathered, broadcasted, reduced) in report.outputs {
            assert_eq!(gathered, expect_gather, "k {k}");
            assert_eq!(broadcasted, expect_bcast, "k {k}");
            assert_eq!(reduced, (p - 1) as f64, "k {k}");
        }
    }
}

/// Pure and hybrid collectives agree bit-for-bit on the same inputs across
/// placements and irregular node shapes.
#[test]
fn pure_and_hybrid_allreduce_agree_numerically() {
    for nodes in [&[4, 4][..], &[5, 3][..], &[2, 3, 3][..]] {
        let report = SimCluster::new(spec(nodes)).run(|env| {
            let w = env.world();
            let vals = [env.world_rank() as f64 * 1.5, -(env.world_rank() as f64)];
            let mut pure = to_bytes(&vals).to_vec();
            coll::allreduce(env, &w, Datatype::F64, ReduceOp::Sum, &mut pure, coll::AllreduceAlgo::Auto);

            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(2));
            let mut ar = ctx.allreduce_init(
                env, Datatype::F64, ReduceOp::Sum, 16, AllreduceMethod::Method2, SyncScheme::Barrier,
            );
            ar.start_allreduce(env, to_bytes(&vals));
            let g = ar.wait(env);
            let hy = ar.window().unwrap().load(env, g, 16);
            env.barrier(ctx.shmem());
            ar.free(env);
            (cast_slice::<f64>(&pure), cast_slice::<f64>(&hy))
        });
        for (pure, hy) in report.outputs {
            for (a, b) in pure.iter().zip(&hy) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b} on nodes {nodes:?}");
            }
        }
    }
}

/// The end-to-end PJRT path: SUMMA through the AOT artifacts equals the
/// native path (skipped when artifacts are absent).
#[test]
fn summa_pjrt_equals_native() {
    if hympi::runtime::SharedRuntime::global().is_none() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let n = 256;
    let cfg = |backend| kernels::summa::SummaCfg {
        n,
        variant: Variant::PureMpi,
        backend,
        threads: 1,
    };
    let pjrt = kernels::summa::run(spec(&[2, 2]), cfg(Backend::Pjrt));
    let native = kernels::summa::run(spec(&[2, 2]), cfg(Backend::Native));
    assert!(
        (pjrt.checksum - native.checksum).abs() < 1e-6 * native.checksum.abs(),
        "pjrt {} vs native {}",
        pjrt.checksum,
        native.checksum
    );
    let want = kernels::summa::expected_checksum(n);
    assert!((pjrt.checksum - want).abs() < 1e-6 * want.abs());
}

/// Poisson PJRT/native parity (the artifact shape poisson_r8_n64 covers
/// an 8-rank 64-grid decomposition).
#[test]
fn poisson_pjrt_equals_native() {
    if hympi::runtime::SharedRuntime::global().is_none() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let cfg = |backend| kernels::poisson::PoissonCfg {
        n: 64,
        tol: 1e-4,
        max_iters: 100,
        variant: Variant::PureMpi,
        backend,
        threads: 1,
    };
    let pjrt = kernels::poisson::run(spec(&[4, 4]), cfg(Backend::Pjrt));
    let native = kernels::poisson::run(spec(&[4, 4]), cfg(Backend::Native));
    assert_eq!(pjrt.iters, native.iters, "identical convergence trajectory");
    assert!((pjrt.checksum - native.checksum).abs() < 1e-9);
}

/// Round-robin placement still yields correct collectives (the §4.4
/// commutativity discussion) — results must match block placement.
#[test]
fn round_robin_placement_correctness() {
    use hympi::mpi::topo::Placement;
    let mut s = spec(&[4, 4]);
    s.placement = Placement::RoundRobin;
    let report = SimCluster::new(s).run(|env| {
        let w = env.world();
        let mut buf = to_bytes(&[env.world_rank() as f64]).to_vec();
        coll::allreduce(env, &w, Datatype::F64, ReduceOp::Sum, &mut buf, coll::AllreduceAlgo::Auto);
        let mut bc = vec![0u8; 64];
        if w.rank() == 3 {
            bc = (0..64u8).collect();
        }
        coll::bcast(env, &w, 3, &mut bc, coll::BcastAlgo::Auto);
        (cast_slice::<f64>(&buf)[0], bc)
    });
    for (sum, bc) in report.outputs {
        assert_eq!(sum, 28.0);
        assert_eq!(bc, (0..64u8).collect::<Vec<_>>());
    }
}

/// Virtual clocks never run backwards through any collective sequence.
#[test]
fn vclock_monotonicity_through_collectives() {
    let report = SimCluster::new(spec(&[3, 5])).run(|env| {
        let w = env.world();
        let mut checkpoints = vec![env.vclock()];
        let mut buf = vec![1u8; 512];
        coll::bcast(env, &w, 0, &mut buf, coll::BcastAlgo::Auto);
        checkpoints.push(env.vclock());
        let mut out = vec![0u8; 512 * 8];
        coll::allgather(env, &w, &buf[..512], &mut out, coll::AllgatherAlgo::Auto);
        checkpoints.push(env.vclock());
        env.barrier(&w);
        checkpoints.push(env.vclock());
        checkpoints
    });
    for cps in report.outputs {
        for pair in cps.windows(2) {
            assert!(pair[1] >= pair[0], "vclock went backwards: {pair:?}");
        }
    }
}
