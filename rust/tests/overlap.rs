//! Integration tests for the split-phase progress engine (ISSUE 5,
//! DESIGN.md §5e): `test()` semantics (false before completion, true
//! exactly once), double-start / forgotten-wait / wrong-root panics,
//! `RootPolicy::Fixed` vs per-start bit-exactness, pipelined-bridge
//! correctness across depths and leader counts, blocking-vs-split-phase
//! bitwise + virtual-time comparisons on irregular shapes under both
//! sync schemes, `wait_any`/`wait_all` over heterogeneous handles, and
//! the overlap wins of the micro probe and the SUMMA/Poisson kernels.

use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::figures::common::overlap_probe;
use hympi::hybrid::{
    AllreduceMethod, HyReq, HybridCtx, LeaderPolicy, RootPolicy, SyncScheme,
};
use hympi::kernels::poisson::{run as poisson_run, PoissonCfg};
use hympi::kernels::summa::{expected_checksum, run as summa_run, SummaCfg};
use hympi::kernels::{Backend, Variant};
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::{cast_slice, to_bytes};

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Deterministic rank-unique byte payload.
fn payload(r: usize, m: usize) -> Vec<u8> {
    (0..m).map(|i| (r.wrapping_mul(167) ^ i.wrapping_mul(31)) as u8).collect()
}

// ---------------------------------------------------------------------
// Blocking vs split-phase: bitwise identical, never slower, strictly
// faster where a sync can hide under compute.
// ---------------------------------------------------------------------

/// Run every op once with `compute_us` of modeled work placed either
/// after the wait (blocking shape) or between start and wait (split
/// shape); return (digest, final vclock).
fn all_ops_program(env: &mut hympi::mpi::env::ProcEnv, split: bool, compute_us: f64) -> (Vec<u8>, f64) {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
    let mut digest = Vec::new();

    // A little macro-free driver: blocking = start; wait; compute.
    // split = start; compute; wait. Identical total compute either way.
    macro_rules! drive {
        ($h:expr, $start:expr) => {{
            $start;
            if split {
                env.compute(compute_us);
                $h.wait(env)
            } else {
                let off = $h.wait(env);
                env.compute(compute_us);
                off
            }
        }};
    }

    for scheme in [SyncScheme::Spin, SyncScheme::Barrier] {
        // allgather
        let mut ag = ctx.allgather_init(env, 64, scheme);
        let mine = payload(me, 64);
        drive!(ag, ag.start_allgather(env, &mine));
        digest.extend_from_slice(&ag.window().unwrap().load(env, 0, 64 * p));
        env.barrier(ctx.shmem());
        ag.free(env);

        // bcast from a child rank
        let root = p - 1;
        let mut bc = ctx.bcast_init(env, 96, scheme);
        let msg = payload(root, 96);
        let arg = (me == root).then_some(&msg[..]);
        drive!(bc, bc.start_bcast(env, root, arg));
        digest.extend_from_slice(&bc.window().unwrap().load(env, 0, 96));
        env.barrier(ctx.shmem());
        bc.free(env);

        // allreduce, both methods
        for method in [AllreduceMethod::Method1, AllreduceMethod::Method2] {
            let mut ar = ctx.allreduce_init(env, Datatype::F64, ReduceOp::Sum, 32, method, scheme);
            let vals: Vec<f64> = (0..4).map(|i| ((me + 1) * (i + 2)) as f64).collect();
            let g = drive!(ar, ar.start_allreduce(env, to_bytes(&vals)));
            digest.extend_from_slice(&ar.window().unwrap().load(env, g, 32));
            env.barrier(ctx.shmem());
            ar.free(env);
        }

        // reduce_scatter
        let mut rs = ctx.reduce_scatter_init(
            env, Datatype::F64, ReduceOp::Sum, 16, AllreduceMethod::Tuned, scheme,
        );
        let full: Vec<f64> = (0..2 * p).map(|e| ((me + 2) * (e + 1)) as f64).collect();
        let off = drive!(rs, rs.start_reduce_scatter(env, to_bytes(&full)));
        digest.extend_from_slice(&rs.window().unwrap().load(env, off, 16));
        env.barrier(ctx.shmem());
        rs.free(env);

        // gather to a mid-cluster child + scatter back from it
        let groot = p / 2;
        let mut g = ctx.gather_init(env, 32, scheme);
        let blk = payload(me, 32);
        drive!(g, g.start_gather(env, groot, &blk));
        if me == groot {
            digest.extend_from_slice(&g.window().unwrap().load(env, 0, 32 * p));
        }
        env.barrier(ctx.shmem());
        g.free(env);

        let mut sc = ctx.scatter_init(env, 32, scheme);
        let full_sc: Vec<u8> = (0..p).flat_map(|r| payload(r + 3, 32)).collect();
        let arg = (me == groot).then_some(&full_sc[..]);
        let off = drive!(sc, sc.start_scatter(env, groot, arg));
        digest.extend_from_slice(&sc.window().unwrap().load(env, off, 32));
        env.barrier(ctx.shmem());
        sc.free(env);
    }
    (digest, env.vclock())
}

#[test]
fn split_phase_is_bitwise_identical_and_never_slower() {
    let compute = 200.0; // µs of hideable work per collective
    let blocking = SimCluster::new(spec(&[5, 3])).run(move |env| all_ops_program(env, false, compute));
    let split = SimCluster::new(spec(&[5, 3])).run(move |env| all_ops_program(env, true, compute));
    let mut total_b = 0.0;
    let mut total_s = 0.0;
    for (r, ((db, vb), (ds, vs))) in blocking.outputs.iter().zip(split.outputs.iter()).enumerate() {
        assert_eq!(db, ds, "rank {r}: split-phase results must be bit-identical");
        assert!(*vs <= *vb + 1e-9, "rank {r}: split {vs} must never exceed blocking {vb}");
        total_b += *vb;
        total_s += *vs;
    }
    // The red/root syncs and releases hide under the inserted compute on
    // every rank: the cluster as a whole must be strictly faster.
    assert!(
        total_s < total_b,
        "split-phase must strictly win in aggregate: {total_s} vs {total_b}"
    );
}

// ---------------------------------------------------------------------
// test() semantics and protocol-error panics.
// ---------------------------------------------------------------------

#[test]
fn test_polls_false_then_completes() {
    let len = 4096usize;
    let report = SimCluster::new(spec(&[4, 4])).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut bc = ctx.bcast_init_split(env, len, SyncScheme::Spin, RootPolicy::Fixed(0), 2);
        let data = payload(9, len);
        if w.rank() != 0 {
            bc.start_bcast(env, 0, None);
            // The root has not even started: completion is impossible.
            assert!(!bc.test(env), "test must be false before the root starts");
        }
        env.barrier(&w);
        if w.rank() == 0 {
            // Fixed root + primary leader + k = 1: the whole schedule
            // (chunk sends + release) runs inside start — test observes
            // completion immediately, and exactly once.
            bc.start_bcast(env, 0, Some(&data));
            assert!(bc.test(env), "root's schedule completes at start");
        }
        env.barrier(&w);
        if w.rank() != 0 {
            // Chunks/flag are now in flight or posted; drive to done.
            // (Children of the non-root node may still see false until
            // their leader progresses — wait() finishes either way.)
            if !bc.test(env) {
                bc.wait(env);
            }
        }
        let got = bc.window().unwrap().load(env, 0, len);
        env.barrier(ctx.shmem());
        bc.free(env);
        got
    });
    let expect = payload(9, len);
    for got in report.outputs {
        assert_eq!(got, expect);
    }
}

#[test]
#[should_panic(expected = "test without start")]
fn test_after_completion_panics() {
    SimCluster::new(spec(&[1])).run(|env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut bc = ctx.bcast_init_split(env, 8, SyncScheme::Spin, RootPolicy::Fixed(0), 1);
        bc.start_bcast(env, 0, Some(&[7u8; 8]));
        assert!(bc.test(env), "single-rank bcast completes at start");
        bc.test(env); // completion already consumed: protocol error
    });
}

#[test]
#[should_panic(expected = "started twice without wait")]
fn double_start_panics() {
    SimCluster::new(spec(&[2])).run(|env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ag = ctx.allgather_init(env, 8, SyncScheme::Spin);
        let mine = [1u8; 8];
        ag.start_allgather(env, &mine);
        ag.start_allgather(env, &mine);
    });
}

#[test]
#[should_panic(expected = "forgotten wait")]
fn free_with_pending_operation_panics() {
    SimCluster::new(spec(&[2])).run(|env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ag = ctx.allgather_init(env, 8, SyncScheme::Spin);
        ag.start_allgather(env, &[1u8; 8]);
        ag.free(env);
    });
}

#[test]
#[should_panic(expected = "different root")]
fn fixed_root_mismatch_panics() {
    SimCluster::new(spec(&[2])).run(|env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut bc = ctx.bcast_init_split(env, 8, SyncScheme::Spin, RootPolicy::Fixed(0), 1);
        let data = [1u8; 8];
        let arg = (w.rank() == 1).then_some(&data[..]);
        bc.start_bcast(env, 1, arg);
    });
}

// ---------------------------------------------------------------------
// RootPolicy::Fixed vs PerStart, and the pipelined bridge.
// ---------------------------------------------------------------------

#[test]
fn fixed_root_matches_per_start_bitwise_and_in_vtime() {
    for root in [0usize, 2, 6] {
        let run = move |policy: RootPolicy| {
            SimCluster::new(spec(&[5, 3])).run(move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
                let mut bc = ctx.bcast_init_split(env, 128, SyncScheme::Spin, policy, 1);
                let data = payload(root, 128);
                env.harness_sync(&w);
                let t0 = env.vclock();
                let arg = (w.rank() == root).then_some(&data[..]);
                bc.start_bcast(env, root, arg);
                bc.wait(env);
                let dt = env.vclock() - t0;
                let got = bc.window().unwrap().load(env, 0, 128);
                env.barrier(ctx.shmem());
                bc.free(env);
                (got, dt)
            })
        };
        let fixed = run(RootPolicy::Fixed(root));
        let per_start = run(RootPolicy::PerStart);
        for (r, ((gf, tf), (gp, tp))) in
            fixed.outputs.iter().zip(per_start.outputs.iter()).enumerate()
        {
            assert_eq!(gf, gp, "root {root} rank {r}: results must match");
            assert_eq!(gf, &payload(root, 128), "root {root} rank {r}");
            assert!(
                (tf - tp).abs() < 1e-9,
                "root {root} rank {r}: Fixed ({tf}) and PerStart ({tp}) charge identically \
                 at depth 1"
            );
        }
    }
}

#[test]
fn pipelined_bcast_and_scatter_match_depth_one() {
    for k in [1usize, 2] {
        for depth in [2usize, 3, 5] {
            let report = SimCluster::new(spec(&[5, 3, 4])).run(move |env| {
                let w = env.world();
                let p = w.size();
                let me = w.rank();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
                let root = 7; // a child on the middle node

                let mut bc =
                    ctx.bcast_init_split(env, 1000, SyncScheme::Spin, RootPolicy::Fixed(root), depth);
                let msg = payload(root, 1000);
                let arg = (me == root).then_some(&msg[..]);
                bc.start_bcast(env, root, arg);
                bc.wait(env);
                let got_bc = bc.window().unwrap().load(env, 0, 1000);
                env.barrier(ctx.shmem());
                bc.free(env);

                let mut sc =
                    ctx.scatter_init_split(env, 48, SyncScheme::Spin, RootPolicy::Fixed(root), depth);
                let full: Vec<u8> = (0..p).flat_map(|r| payload(r + 11, 48)).collect();
                let arg = (me == root).then_some(&full[..]);
                sc.start_scatter(env, root, arg);
                let off = sc.wait(env);
                let got_sc = sc.window().unwrap().load(env, off, 48);
                env.barrier(ctx.shmem());
                sc.free(env);
                (got_bc, got_sc)
            });
            for (r, (bcast, scat)) in report.outputs.into_iter().enumerate() {
                assert_eq!(bcast, payload(7, 1000), "bcast k {k} depth {depth} rank {r}");
                assert_eq!(scat, payload(r + 11, 48), "scatter k {k} depth {depth} rank {r}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// wait_any / wait_all over heterogeneous handles.
// ---------------------------------------------------------------------

#[test]
fn wait_any_prefers_the_satisfiable_request() {
    let report = SimCluster::new(spec(&[4, 4])).run(|env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ar = ctx.allreduce_init(
            env, Datatype::F64, ReduceOp::Sum, 8, AllreduceMethod::Tuned, SyncScheme::Spin,
        );
        let mut bc = ctx.bcast_init_split(env, 512, SyncScheme::Spin, RootPolicy::Fixed(0), 2);
        let msg = payload(1, 512);

        ar.start_allreduce(env, to_bytes(&[(me + 1) as f64]));
        bc.start_bcast(env, 0, (me == 0).then_some(&msg[..]));
        // Root 0's bridge chunks and its node's release flag were posted
        // inside its start; this barrier makes them visible everywhere.
        env.barrier(&w);

        // The allreduce sits first, but it needs blocking stages; the
        // broadcast is already satisfiable without blocking on node-0
        // ranks and on the non-root node's leader — fairness demands it
        // completes there.
        let first = {
            let mut reqs: [&mut dyn HyReq; 2] = [&mut ar, &mut bc];
            HybridCtx::wait_any(env, &mut reqs)
        };
        let deterministic_bcast_first = me == 0 || ctx.node_index() == 0 || ctx.is_leader();
        if deterministic_bcast_first {
            assert_eq!(first, 1, "rank {me}: the posted bcast must complete first");
        }
        // Drive the remaining handle.
        if first == 1 {
            ar.wait(env);
        } else {
            bc.wait(env);
        }

        let sum = cast_slice::<f64>(&ar.window().unwrap().load(env, ar.result_offset(), 8))[0];
        let got = bc.window().unwrap().load(env, 0, 512);
        env.barrier(ctx.shmem());
        ar.free(env);
        bc.free(env);
        (sum, got)
    });
    let expect_sum: f64 = (1..=8).map(|r| r as f64).sum();
    for (sum, got) in report.outputs {
        assert_eq!(sum, expect_sum);
        assert_eq!(got, payload(1, 512));
    }
}

#[test]
fn wait_all_completes_heterogeneous_handles() {
    let report = SimCluster::new(spec(&[5, 3])).run(|env| {
        let w = env.world();
        let p = w.size();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ag = ctx.allgather_init(env, 16, SyncScheme::Spin);
        let mut rs = ctx.reduce_scatter_init(
            env, Datatype::F64, ReduceOp::Sum, 8, AllreduceMethod::Tuned, SyncScheme::Barrier,
        );
        let mine = payload(me, 16);
        ag.start_allgather(env, &mine);
        let full: Vec<f64> = (0..p).map(|e| ((me + 1) * (e + 1)) as f64).collect();
        rs.start_reduce_scatter(env, to_bytes(&full));

        let offs = {
            let mut reqs: [&mut dyn HyReq; 2] = [&mut ag, &mut rs];
            HybridCtx::wait_all(env, &mut reqs)
        };
        let gathered = ag.window().unwrap().load(env, offs[0], 16 * p);
        let reduced = cast_slice::<f64>(&rs.window().unwrap().load(env, offs[1], 8))[0];
        env.barrier(ctx.shmem());
        ag.free(env);
        rs.free(env);
        (gathered, reduced)
    });
    let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 16)).collect();
    let rank_sum: f64 = (1..=8).map(|r| r as f64).sum();
    for (r, (gathered, reduced)) in report.outputs.into_iter().enumerate() {
        assert_eq!(gathered, expect, "rank {r}");
        assert_eq!(reduced, rank_sum * (r + 1) as f64, "rank {r}");
    }
}

// ---------------------------------------------------------------------
// Overlap wins: micro probe and the kernel ports.
// ---------------------------------------------------------------------

#[test]
fn overlap_probe_hides_the_bridge_under_compute() {
    let (blocking, split) =
        overlap_probe(ClusterSpec::preset(Preset::VulcanSb, 2), 256 * 1024, 1_500.0, 4, true);
    assert!(
        split < blocking,
        "split-phase bcast ({split}) must be strictly below blocking ({blocking})"
    );
    // The compute itself is a floor for the split leg.
    assert!(split >= 1_500.0, "split leg ({split}) cannot undercut its own compute");
}

#[test]
fn summa_overlap_reproduces_results_and_wins_native() {
    // Small modeled-compute shape (real arithmetic, modeled charge):
    // results verified against the oracle, and the split-phase variant
    // must be strictly faster — the column broadcasts cross nodes.
    let n = 192;
    let cfg = |variant| SummaCfg { n, variant, backend: Backend::Modeled, threads: 1 };
    let blocking = summa_run(spec(&[2, 2]), cfg(Variant::HybridMpiMpi));
    let split = summa_run(spec(&[2, 2]), cfg(Variant::HybridOverlap));
    let want = expected_checksum(n);
    for rep in [&blocking, &split] {
        assert!(
            (rep.checksum - want).abs() < 1e-6 * want.abs().max(1.0),
            "{:?}: checksum {} vs {want}",
            rep.variant,
            rep.checksum
        );
    }
    assert!(
        split.total_us < blocking.total_us,
        "split-phase SUMMA ({}) must beat blocking ({}) with cross-node panel bcasts",
        split.total_us,
        blocking.total_us
    );
}

#[test]
fn summa_overlap_wins_at_quarter_mib_panels() {
    // The PR-5 acceptance regime at test scale: 182×182 f64 panels
    // (259 KiB ≥ 256 KiB) on a two-node 16-rank grid, phantom compute
    // (modeled charge only). The engine-scale 484-rank variant of this
    // bound runs in `bench_all` (full mode) and in the ignored test
    // below.
    let n = 728; // 16 ranks, 4×4 grid, nb = 182
    let cfg = |variant| SummaCfg { n, variant, backend: Backend::Phantom, threads: 1 };
    let blocking = summa_run(spec(&[8, 8]), cfg(Variant::HybridMpiMpi));
    let split = summa_run(spec(&[8, 8]), cfg(Variant::HybridOverlap));
    assert_eq!(blocking.iters, split.iters);
    assert!(
        split.total_us < blocking.total_us,
        "split-phase SUMMA ({}) must be strictly below blocking ({}) at ≥256 KiB panels",
        split.total_us,
        blocking.total_us
    );
}

#[test]
#[ignore = "engine-scale (484 ranks): run explicitly in a toolchain'd environment"]
fn summa_overlap_wins_at_engine_scale() {
    let cfg = |variant| SummaCfg { n: 4004, variant, backend: Backend::Phantom, threads: 1 };
    let s = ClusterSpec::preset_total_ranks(Preset::VulcanSb, 484);
    let blocking = summa_run(s.clone(), cfg(Variant::HybridMpiMpi));
    let split = summa_run(s, cfg(Variant::HybridOverlap));
    assert!(split.total_us < blocking.total_us);
}

#[test]
fn poisson_overlap_matches_blocking_and_wins() {
    let cfg = |variant| PoissonCfg {
        n: 128,
        tol: 0.0, // fixed 30 iterations
        max_iters: 30,
        variant,
        backend: Backend::Modeled,
        threads: 1,
    };
    let blocking = poisson_run(spec(&[8, 8]), cfg(Variant::HybridMpiMpi));
    let split = poisson_run(spec(&[8, 8]), cfg(Variant::HybridOverlap));
    assert_eq!(blocking.iters, split.iters, "same iteration count");
    assert!(
        (blocking.checksum - split.checksum).abs() < 1e-12 * blocking.checksum.abs().max(1.0),
        "phased sweep must be bit-compatible: {} vs {}",
        split.checksum,
        blocking.checksum
    );
    assert!(
        split.total_us < blocking.total_us,
        "halo-overlapped Poisson ({}) must be strictly below blocking ({})",
        split.total_us,
        blocking.total_us
    );
}

#[test]
fn poisson_overlap_converges_identically_native() {
    let cfg = |variant| PoissonCfg {
        n: 32,
        tol: 1e-3,
        max_iters: 500,
        variant,
        backend: Backend::Native,
        threads: 1,
    };
    let blocking = poisson_run(spec(&[4, 4]), cfg(Variant::HybridMpiMpi));
    let split = poisson_run(spec(&[4, 4]), cfg(Variant::HybridOverlap));
    assert!(blocking.iters < 500 && split.iters == blocking.iters);
    assert!((blocking.checksum - split.checksum).abs() < 1e-12);
}
