//! Integration tests for the persistent-collective engine: bit-exactness
//! of plan-cached hybrid collectives vs the pure-MPI references on
//! irregular node shapes under both §4.5 sync schemes, plus plan-cache
//! hit/reuse assertions (no per-iteration window allocation or table
//! rebuild).

use hympi::coll::{CollOp, Flavor, PlanCache, PlanKey};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::SyncScheme;
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::{cast_slice, to_bytes};

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Deterministic rank-unique byte payload.
fn payload(r: usize, m: usize) -> Vec<u8> {
    (0..m).map(|i| (r.wrapping_mul(131) ^ i.wrapping_mul(29)) as u8).collect()
}

/// Every op, hybrid vs pure, one irregular cluster shape, one scheme.
/// Data is integer-valued f64 (or raw bytes), so every reduction order
/// is exact and the comparison is bit-for-bit.
fn check_all_ops(nodes: &'static [usize], scheme: SyncScheme) {
    let report = SimCluster::new(spec(nodes)).run(move |env| {
        let w = env.world();
        let p = w.size();
        let me = w.rank();
        let mut cache = PlanCache::new();
        let fl = Flavor::hybrid(scheme);
        let n = 4usize; // doubles per rank/block

        // allgather --------------------------------------------------
        let mine: Vec<f64> = (0..n).map(|i| (me * n + i) as f64).collect();
        let mut pure = vec![0u8; n * 8 * p];
        cache.allgather(env, &w, Flavor::Pure, to_bytes(&mine), Some(&mut pure));
        let mut hy = vec![0u8; n * 8 * p];
        cache.allgather(env, &w, fl, to_bytes(&mine), Some(&mut hy));
        assert_eq!(pure, hy, "allgather {nodes:?} {scheme:?}");

        // bcast, rooted at a child on the last node -------------------
        let root = p - 1;
        let msg = payload(root, 100);
        let mut pure_bc = if me == root { msg.clone() } else { vec![0u8; 100] };
        cache.bcast(env, &w, Flavor::Pure, root, 100, Some(&mut pure_bc));
        let mut hy_bc = if me == root { msg.clone() } else { vec![0u8; 100] };
        cache.bcast(env, &w, fl, root, 100, Some(&mut hy_bc));
        assert_eq!(pure_bc, hy_bc, "bcast {nodes:?} {scheme:?}");

        // allreduce ---------------------------------------------------
        let vals: Vec<f64> = (0..n).map(|i| ((me + 1) * (i + 3)) as f64).collect();
        let mut pure_ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut pure_ar);
        let mut hy_ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut hy_ar);
        assert_eq!(pure_ar, hy_ar, "allreduce {nodes:?} {scheme:?}");

        // reduce_scatter ----------------------------------------------
        let full: Vec<f64> = (0..n * p).map(|e| ((me + 1) * (e + 1)) as f64).collect();
        let mut pure_rs = vec![0u8; n * 8];
        cache.reduce_scatter(
            env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut pure_rs,
        );
        let mut hy_rs = vec![0u8; n * 8];
        cache.reduce_scatter(
            env, &w, fl, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut hy_rs,
        );
        assert_eq!(pure_rs, hy_rs, "reduce_scatter {nodes:?} {scheme:?}");

        // gather to a mid-cluster child -------------------------------
        let groot = p / 2;
        let blk = payload(me, 32);
        let mut pure_g = vec![0u8; 32 * p];
        let rb = (me == groot).then_some(&mut pure_g[..]);
        cache.gather(env, &w, Flavor::Pure, groot, &blk, rb);
        let mut hy_g = vec![0u8; 32 * p];
        let rb = (me == groot).then_some(&mut hy_g[..]);
        cache.gather(env, &w, fl, groot, &blk, rb);
        if me == groot {
            assert_eq!(pure_g, hy_g, "gather {nodes:?} {scheme:?}");
        }

        // scatter from the same root ----------------------------------
        let full_sc: Vec<u8> = (0..p).flat_map(|r| payload(r + 7, 32)).collect();
        let mut pure_sc = vec![0u8; 32];
        cache.scatter(env, &w, Flavor::Pure, groot, (me == groot).then_some(&full_sc[..]), &mut pure_sc);
        let mut hy_sc = vec![0u8; 32];
        cache.scatter(env, &w, fl, groot, (me == groot).then_some(&full_sc[..]), &mut hy_sc);
        assert_eq!(pure_sc, hy_sc, "scatter {nodes:?} {scheme:?}");
        assert_eq!(pure_sc, payload(me + 7, 32));

        env.barrier(&w);
        cache.free(env);
        cast_slice::<f64>(&pure_ar)
    });
    // Cross-rank agreement of the reduced vector.
    let first = &report.outputs[0];
    for got in &report.outputs {
        assert_eq!(got, first);
    }
}

#[test]
fn hybrid_matches_pure_on_irregular_shapes_spin() {
    // The ISSUE's canonical irregular shape plus a non-power-of-two
    // bridge (3 nodes) and a single-node degenerate case.
    check_all_ops(&[5, 3, 4], SyncScheme::Spin);
    check_all_ops(&[5, 3], SyncScheme::Spin);
    check_all_ops(&[7], SyncScheme::Spin);
}

#[test]
fn hybrid_matches_pure_on_irregular_shapes_barrier() {
    check_all_ops(&[5, 3, 4], SyncScheme::Barrier);
    check_all_ops(&[3, 2, 2, 3], SyncScheme::Barrier); // 4-node non-pow2 blocks
}

#[test]
fn plan_cache_reuses_plans_windows_and_tables() {
    let report = SimCluster::new(spec(&[5, 3, 4])).run(|env| {
        let w = env.world();
        let mut cache = PlanCache::new();
        let fl = Flavor::hybrid(SyncScheme::Spin);

        // An application-shaped inner loop: the same three collectives,
        // ten iterations.
        let iters = 10usize;
        for it in 0..iters {
            let mine = vec![it as u8; 64];
            cache.allgather(env, &w, fl, &mine, None);
            let mut buf = to_bytes(&[(w.rank() + it) as f64]).to_vec();
            cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut buf);
            let mut bc = vec![it as u8; 16];
            cache.bcast(env, &w, fl, 0, 16, Some(&mut bc));
        }
        // Exactly three plans were ever built; every other invocation
        // reused one (no window re-allocation, no table rebuild).
        let stats = (cache.misses(), cache.hits(), cache.len());

        // The backing window of the allgather plan is stable.
        let key = PlanKey::new(&w, CollOp::Allgather, 64, Datatype::U8, None, fl, 0);
        let w0 = cache.window_of(&key).map(|h| h.win.as_ref() as *const _ as usize).unwrap();
        let mine = vec![9u8; 64];
        cache.allgather(env, &w, fl, &mine, None);
        let w1 = cache.window_of(&key).map(|h| h.win.as_ref() as *const _ as usize).unwrap();

        env.barrier(&w);
        cache.free(env);
        (stats, w0 == w1)
    });
    for ((misses, hits, len), stable) in report.outputs {
        assert_eq!(misses, 3, "three plans built once");
        assert_eq!(hits, 3 * 10, "every loop iteration hit the cache");
        assert_eq!(len, 3);
        assert!(stable, "shared window must survive across executions");
    }
}

#[test]
fn per_communicator_one_off_state_is_shared() {
    // Multiple plans on one communicator must share the session context
    // (one set of splits), like SUMMA's row/column pattern.
    let report = SimCluster::new(spec(&[4, 4])).run(|env| {
        let w = env.world();
        let mut cache = PlanCache::new();
        let fl = Flavor::hybrid(SyncScheme::Spin);
        cache.plan(env, &w, CollOp::Allgather, 32, Datatype::U8, None, fl);
        cache.plan(env, &w, CollOp::Bcast, 64, Datatype::U8, None, fl);
        cache.plan(env, &w, CollOp::Allreduce, 8, Datatype::F64, Some(ReduceOp::Sum), fl);
        let ctx = cache.hybrid_ctx(env, &w, 1).unwrap();
        let stats = (cache.len(), ctx.shmem_size(), ctx.nnodes());
        env.barrier(&w);
        cache.free(env);
        stats
    });
    for (len, shmem_size, bridge_size) in report.outputs {
        assert_eq!(len, 3);
        assert!(shmem_size == 4);
        assert_eq!(bridge_size, 2);
    }
}

#[test]
fn kernels_share_results_across_variants_via_plans() {
    // End-to-end: the ported kernels still cross-validate (Poisson pure
    // vs hybrid convergence trajectories are identical).
    use hympi::kernels::poisson::{run, PoissonCfg};
    use hympi::kernels::{Backend, Variant};
    let cfg = |variant| PoissonCfg {
        n: 32,
        tol: 1e-3,
        max_iters: 300,
        variant,
        backend: Backend::Native,
        threads: 1,
    };
    let pure = run(spec(&[4, 4]), cfg(Variant::PureMpi));
    let hy = run(spec(&[4, 4]), cfg(Variant::HybridMpiMpi));
    assert_eq!(pure.iters, hy.iters);
    assert!((pure.checksum - hy.checksum).abs() < 1e-12);
}
