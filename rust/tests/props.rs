//! Property-based tests over the coordinator invariants (routing/
//! translation tables, placement partitioning, collective payload
//! permutations, state management), using the in-repo quickprop harness
//! (see `util::quickprop` — proptest is unavailable offline).

use hympi::coll;
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{HybridCtx, LeaderPolicy};
use hympi::mpi::topo::{Placement, Topology};
use hympi::util::quickprop::{default_cases, run};
use hympi::util::Rng;

/// Random small cluster shape: 1–4 nodes × 1–6 ranks.
fn arb_nodes(rng: &mut Rng) -> Vec<usize> {
    let n = 1 + rng.below(4);
    (0..n).map(|_| 1 + rng.below(6)).collect()
}

fn spec_for(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

#[test]
fn prop_placement_is_a_partition() {
    run(
        "placement-is-a-partition",
        default_cases(),
        |rng| (arb_nodes(rng), if rng.below(2) == 0 { Placement::Block } else { Placement::RoundRobin }),
        |(nodes, placement)| {
            let t = Topology::new(nodes, *placement);
            let world = t.world_size();
            // Every rank appears on exactly one node, at its claimed slot.
            let mut seen = vec![0usize; world];
            for n in 0..t.nnodes() {
                for (slot, &r) in t.ranks_on(n).iter().enumerate() {
                    seen[r] += 1;
                    if t.node_of(r) != n || t.slot_of(r) != slot {
                        return Err(format!("rank {r}: node/slot mismatch"));
                    }
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!("not a partition: {seen:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_leader_is_lowest_rank_on_node() {
    run(
        "leader-is-lowest-rank",
        default_cases(),
        |rng| (arb_nodes(rng), if rng.below(2) == 0 { Placement::Block } else { Placement::RoundRobin }),
        |(nodes, placement)| {
            let t = Topology::new(nodes, *placement);
            for n in 0..t.nnodes() {
                let leader = t.leader_of_node(n);
                if t.ranks_on(n).iter().any(|&r| r < leader) {
                    return Err(format!("node {n}: leader {leader} is not minimal"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transtables_are_consistent_bijections() {
    run(
        "transtable-bijection",
        16, // cluster spin-up per case — keep the count moderate
        |rng| arb_nodes(rng),
        |nodes| {
            let report = SimCluster::new(spec_for(nodes)).run(|env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
                let t = ctx.tables(env);
                (t.shmem.clone(), t.bridge.clone(), ctx.shmem_size(), ctx.nnodes())
            });
            let world: usize = nodes.iter().sum();
            for (shmem, bridge, _, bridge_size) in &report.outputs {
                if shmem.len() != world || bridge.len() != world {
                    return Err("table length".into());
                }
                // Within a node (same bridge idx), shmem ranks are 0..k distinct.
                for b in 0..*bridge_size {
                    let mut ranks: Vec<usize> = (0..world)
                        .filter(|&r| bridge[r] == b)
                        .map(|r| shmem[r])
                        .collect();
                    ranks.sort_unstable();
                    let expect: Vec<usize> = (0..ranks.len()).collect();
                    if ranks != expect {
                        return Err(format!("node {b}: shmem ranks {ranks:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bcast_any_root_any_algo_delivers_exact_payload() {
    run(
        "bcast-correctness",
        12,
        |rng| {
            let nodes = arb_nodes(rng);
            let world: usize = nodes.iter().sum();
            let root = rng.below(world);
            let len = 1 + rng.below(3000);
            let algo = match rng.below(4) {
                0 => coll::BcastAlgo::Binomial,
                1 => coll::BcastAlgo::SplitBinary { seg: 1 + rng.below(512) },
                2 => coll::BcastAlgo::Pipeline { seg: 1 + rng.below(512) },
                _ => coll::BcastAlgo::ScatterAllgather,
            };
            let mut payload = vec![0u8; len];
            rng.fill_bytes(&mut payload);
            (nodes, root, algo, payload)
        },
        |(nodes, root, algo, payload)| {
            let (root, algo) = (*root, *algo);
            let payload2 = payload.clone();
            let len = payload.len();
            let report = SimCluster::new(spec_for(nodes)).run(move |env| {
                let w = env.world();
                let mut buf = if w.rank() == root { payload2.clone() } else { vec![0u8; len] };
                coll::bcast(env, &w, root, &mut buf, algo);
                buf
            });
            for (r, got) in report.outputs.iter().enumerate() {
                if got != payload {
                    return Err(format!("rank {r} corrupted payload (algo {algo:?})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allgather_is_exact_concatenation() {
    run(
        "allgather-concatenation",
        12,
        |rng| {
            let nodes = arb_nodes(rng);
            let m = 1 + rng.below(700);
            let algo = match rng.below(3) {
                0 => coll::AllgatherAlgo::Bruck,
                1 => coll::AllgatherAlgo::Ring,
                _ => coll::AllgatherAlgo::Auto,
            };
            (nodes, m, algo)
        },
        |(nodes, m, algo)| {
            let (m, algo) = (*m, *algo);
            let report = SimCluster::new(spec_for(nodes)).run(move |env| {
                let w = env.world();
                let mine: Vec<u8> = (0..m).map(|i| (w.rank() * 37 + i) as u8).collect();
                let mut out = vec![0u8; m * w.size()];
                coll::allgather(env, &w, &mine, &mut out, algo);
                out
            });
            let world: usize = nodes.iter().sum();
            let expect: Vec<u8> =
                (0..world).flat_map(|r| (0..m).map(move |i| (r * 37 + i) as u8)).collect();
            for got in &report.outputs {
                if got != &expect {
                    return Err(format!("mismatch (algo {algo:?}, m {m})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allreduce_matches_oracle_within_fp_reordering() {
    run(
        "allreduce-oracle",
        12,
        |rng| {
            let nodes = arb_nodes(rng);
            let n_elems = 1 + rng.below(600);
            let algo = if rng.below(2) == 0 {
                coll::AllreduceAlgo::RecursiveDoubling
            } else {
                coll::AllreduceAlgo::Rabenseifner
            };
            (nodes, n_elems, algo)
        },
        |(nodes, n_elems, algo)| {
            let (n_elems, algo) = (*n_elems, *algo);
            let report = SimCluster::new(spec_for(nodes)).run(move |env| {
                let w = env.world();
                let vals: Vec<f64> = (0..n_elems).map(|i| ((w.rank() + 1) * (i + 1)) as f64 * 0.25).collect();
                let mut buf = hympi::util::to_bytes(&vals).to_vec();
                coll::allreduce(
                    env,
                    &w,
                    hympi::mpi::Datatype::F64,
                    hympi::mpi::ReduceOp::Sum,
                    &mut buf,
                    algo,
                );
                hympi::util::cast_slice::<f64>(&buf)
            });
            let world: usize = nodes.iter().sum();
            let rank_sum: f64 = (1..=world).map(|r| r as f64).sum();
            for got in &report.outputs {
                for (i, &v) in got.iter().enumerate() {
                    let expect = rank_sum * (i + 1) as f64 * 0.25;
                    if (v - expect).abs() > 1e-9 * expect.abs().max(1.0) {
                        return Err(format!("elem {i}: {v} vs {expect} (algo {algo:?})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_vclocks_nonnegative_and_finite() {
    run(
        "vclock-sanity",
        16,
        |rng| arb_nodes(rng),
        |nodes| {
            let report = SimCluster::new(spec_for(nodes)).run(|env| {
                let w = env.world();
                env.barrier(&w);
                env.vclock()
            });
            for v in &report.vtimes {
                if !v.is_finite() || *v < 0.0 {
                    return Err(format!("bad vclock {v}"));
                }
            }
            Ok(())
        },
    );
}
