//! Integration tests for the correctness-analysis subsystem (ISSUE 6):
//! the static schedule verifier over *real* exported schedules — a clean
//! sweep, then mutation tests that corrupt the exports (dropped arrive,
//! skipped yellow release, mismatched bridge tag, shrunk window, root
//! disagreement) and assert each is flagged with a diagnostic naming the
//! offending rank/stage pair — and the happens-before race detector
//! driven end-to-end: clean under the window discipline, reporting when
//! a rank re-stages an epoch while a peer still reads the previous one.

use hympi::analysis::race;
use hympi::analysis::schedule::{Diagnostic, RankSchedule, StageModel};
use hympi::analysis::{verify_handle, verify_survivors, RaceDetector};
use hympi::coll::{Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, RootPolicy, SyncScheme};
use hympi::mpi::{Datatype, ReduceOp};

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Build one handle per rank on a [5, 3] cluster, export its schedule,
/// free it, and return the all-rank schedule set. `build` must be
/// deterministic — every rank runs it in the same collective order.
fn export<F>(k: usize, root: usize, build: F) -> Vec<RankSchedule>
where
    F: Fn(&std::rc::Rc<HybridCtx>, &mut hympi::mpi::env::ProcEnv) -> hympi::hybrid::HyColl
        + Send
        + Sync
        + 'static,
{
    let report = SimCluster::new(spec(&[5, 3])).run(move |env| {
        let w = env.world();
        let policy = if k == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(k) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = build(&ctx, env);
        let s = h.export_schedule(root);
        env.barrier(&w);
        h.free(env);
        s
    });
    report.outputs
}

#[test]
fn real_exports_verify_clean() {
    for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
        for k in [1, 2] {
            let ag = export(k, 0, move |ctx, env| ctx.allgather_init(env, 64, scheme));
            let diags = verify_handle(&ag);
            assert!(diags.is_empty(), "allgather k{k} {scheme:?}: {diags:?}");

            let bc = export(k, 7, move |ctx, env| {
                ctx.bcast_init_split(env, 96, scheme, RootPolicy::Fixed(7), 2)
            });
            let diags = verify_handle(&bc);
            assert!(diags.is_empty(), "bcast k{k} {scheme:?}: {diags:?}");

            let ar = export(k, 0, move |ctx, env| {
                ctx.allreduce_init(
                    env,
                    Datatype::F64,
                    ReduceOp::Sum,
                    64,
                    AllreduceMethod::Method2,
                    scheme,
                )
            });
            let diags = verify_handle(&ar);
            assert!(diags.is_empty(), "allreduce k{k} {scheme:?}: {diags:?}");
        }
    }
}

#[test]
fn mutation_dropped_arrive_is_flagged() {
    let mut s = export(1, 0, |ctx, env| ctx.allgather_init(env, 64, SyncScheme::Spin));
    let i = s[0]
        .stages
        .iter()
        .position(|st| matches!(st, StageModel::Arrive { .. }))
        .expect("allgather opens with a red sync");
    s[0].stages[i] = StageModel::Skip;
    let diags = verify_handle(&s);
    assert!(
        diags.iter().any(
            |d| matches!(d, Diagnostic::AwaitWithoutArrive { rank: 0, stage, .. } if *stage == i + 1)
        ),
        "got: {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::BarrierArity { .. })),
        "the group must also be short one participant: {diags:?}"
    );
}

#[test]
fn mutation_skipped_yellow_release_is_flagged() {
    let mut s = export(1, 0, |ctx, env| ctx.allgather_init(env, 64, SyncScheme::Spin));
    let (r, i) = s
        .iter()
        .enumerate()
        .find_map(|(r, sched)| {
            sched
                .stages
                .iter()
                .position(|st| matches!(st, StageModel::Post { .. }))
                .map(|i| (r, i))
        })
        .expect("spin schedules carry a yellow post on the primary leader");
    s[r].stages[i] = StageModel::Skip;
    let diags = verify_handle(&s);
    assert!(
        diags
            .iter()
            .any(|d| matches!(d, Diagnostic::MissingRelease { episode: 0, .. })),
        "got: {diags:?}"
    );
}

#[test]
fn mutation_mismatched_bridge_tag_orphans_both_sides() {
    // Pipelined fixed-root bcast: the root node's leader streams chunks
    // to every other node's leader.
    let mut s = export(1, 7, |ctx, env| {
        ctx.bcast_init_split(env, 96, SyncScheme::Spin, RootPolicy::Fixed(7), 2)
    });
    let mut mutated = None;
    'outer: for sched in s.iter_mut() {
        let rank = sched.rank;
        for (i, st) in sched.stages.iter_mut().enumerate() {
            if let StageModel::Work { msgs, .. } = st {
                if let Some(m) = msgs.iter_mut().find(|m| !m.send) {
                    m.tag += 17;
                    mutated = Some((rank, i));
                    break 'outer;
                }
            }
        }
    }
    let (rank, _) = mutated.expect("a receiving leader exists on the 2-node shape");
    let diags = verify_handle(&s);
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::UnmatchedRecv { rank: r, .. } if *r == rank)),
        "got: {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::UnmatchedSend { .. })),
        "the orphaned sender must be named too: {diags:?}"
    );
}

#[test]
fn mutation_shrunk_window_is_flagged() {
    let mut s = export(1, 0, |ctx, env| ctx.allgather_init(env, 64, SyncScheme::Spin));
    s[0].win_len = 8; // the leader's Work writes the full gathered vector
    let diags = verify_handle(&s);
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::OutOfWindow { rank: 0, .. })),
        "got: {diags:?}"
    );
}

#[test]
fn mutation_root_disagreement_is_flagged() {
    // Rank 2 exports its per-start gather schedule against a different
    // root than everyone else.
    let report = SimCluster::new(spec(&[5, 3])).run(|env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = ctx.gather_init(env, 48, SyncScheme::Spin);
        let root = if w.rank() == 2 { 1 } else { 0 };
        let s = h.export_schedule(root);
        env.barrier(&w);
        h.free(env);
        s
    });
    let diags = verify_handle(&report.outputs);
    assert!(
        diags.iter().any(
            |d| matches!(d, Diagnostic::RootMismatch { roots } if roots.contains(&(2, 1)))
        ),
        "got: {diags:?}"
    );
}

#[test]
fn multiply_corrupted_schedule_reports_every_violation_deterministically() {
    // Three *independent* corruptions in one schedule set — a dropped
    // root-node arrive, a mis-tagged chunk send, a shrunk window — must
    // every one be reported (no first-error bailout), in an order stable
    // across invocations (the CI gate diffs verifier output).
    let mut s = export(1, 7, |ctx, env| {
        ctx.bcast_init_split(env, 96, SyncScheme::Spin, RootPolicy::Fixed(7), 2)
    });
    let i = s[6]
        .stages
        .iter()
        .position(|st| matches!(st, StageModel::Arrive { .. }))
        .expect("root-node ranks carry the red sync");
    s[6].stages[i] = StageModel::Skip;
    let sender = s
        .iter_mut()
        .find_map(|sched| {
            let rank = sched.rank;
            sched.stages.iter_mut().find_map(|st| match st {
                StageModel::Work { msgs, .. } => msgs.iter_mut().find(|m| m.send).map(|m| {
                    m.tag += 17;
                    rank
                }),
                _ => None,
            })
        })
        .expect("the root node's leader streams chunk sends");
    s[0].win_len = 8;

    let diags = verify_handle(&s);
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::AwaitWithoutArrive { rank: 6, .. })),
        "corruption 1 (dropped arrive): {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::BarrierArity { .. })),
        "corruption 1 (short group): {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::UnmatchedSend { rank, .. } if *rank == sender)),
        "corruption 2 (orphaned send): {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::UnmatchedRecv { .. })),
        "corruption 2 (orphaned recv): {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::OutOfWindow { rank: 0, .. })),
        "corruption 3 (shrunk window): {diags:?}"
    );
    assert!(diags.len() >= 5, "all three corruptions report: {diags:?}");
    let again = verify_handle(&s);
    assert_eq!(
        format!("{diags:?}"),
        format!("{again:?}"),
        "diagnostic ordering must be deterministic across invocations"
    );
}

#[test]
fn verify_survivors_reports_every_violation_deterministically() {
    // Pretend a shrink to survivors {0..5} happened but the schedules
    // were never rebuilt: the set mismatch, *every* rank still naming
    // dead root 7, and a handle-level corruption must all surface, in a
    // stable order (mismatch first, root retentions in rank order).
    let mut s = export(1, 7, |ctx, env| {
        ctx.bcast_init_split(env, 96, SyncScheme::Spin, RootPolicy::Fixed(7), 2)
    });
    s[0].win_len = 8;
    let expected: Vec<usize> = (0..6).collect();
    let diags = verify_survivors(&s, &expected);
    let again = verify_survivors(&s, &expected);
    assert_eq!(
        format!("{diags:?}"),
        format!("{again:?}"),
        "diagnostic ordering must be deterministic across invocations"
    );
    assert!(
        matches!(&diags[0], Diagnostic::SurvivorSetMismatch { .. }),
        "the set mismatch leads: {diags:?}"
    );
    let retained: Vec<usize> = diags
        .iter()
        .filter_map(|d| match d {
            Diagnostic::DeadRootRetained { rank, root: 7 } => Some(*rank),
            _ => None,
        })
        .collect();
    assert_eq!(
        retained,
        (0..8).collect::<Vec<_>>(),
        "every rank retaining the dead root is named, in rank order: {diags:?}"
    );
    assert!(
        diags.iter().any(|d| matches!(d, Diagnostic::OutOfWindow { rank: 0, .. })),
        "handle-level checks ride along: {diags:?}"
    );
}

#[test]
fn plan_cache_verify_is_clean_on_hybrid_plans() {
    let report = SimCluster::new(spec(&[5, 3])).run(|env| {
        let w = env.world();
        let mut cache = PlanCache::new();
        let fl = Flavor::hybrid(SyncScheme::Spin);
        let mine = vec![env.world_rank() as u8; 16];
        cache.allgather(env, &w, fl, &mine, None);
        let mut buf = vec![1u8; 32];
        cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut buf);
        let exports = cache.export_schedules(0);
        let diags = cache.verify(0);
        env.barrier(&w);
        cache.free(env);
        (exports.len(), diags.len())
    });
    for (nexports, ndiags) in report.outputs {
        assert_eq!(nexports, 2, "both hybrid plans must export a schedule");
        assert_eq!(ndiags, 0, "rank-local verification must be clean");
    }
}

#[test]
fn race_detector_clean_under_the_window_discipline() {
    let det = RaceDetector::new(5, 42);
    let det2 = det.clone();
    SimCluster::new(spec(&[3, 2])).run(move |env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ag = ctx.allgather_init(env, 32, SyncScheme::Spin);
        let mut bc = ctx.bcast_init(env, 48, SyncScheme::Barrier);
        race::install(&det2, me);
        let block = vec![me as u8; 32];
        let payload = vec![9u8; 48];
        for epoch in 0..2 {
            ag.start_allgather(env, &block);
            ag.wait(env);
            bc.start_bcast(env, 0, (me == 0).then_some(&payload[..]));
            bc.wait(env);
            if epoch == 1 {
                // Reads are safe on the last epoch: nobody re-stages.
                std::hint::black_box(ag.result_view(32).unwrap()[0]);
                std::hint::black_box(bc.result_view(48).unwrap()[0]);
            }
        }
        race::uninstall();
        env.barrier(&w);
        ag.free(env);
        bc.free(env);
    });
    let reports = det.reports();
    assert!(reports.is_empty(), "expected clean, got: {reports:?}");
}

#[test]
fn race_detector_flags_stale_epoch_read_vs_restaging() {
    // Rank 1 reads the gathered result *after* its wait, while rank 0 —
    // already past its own wait (the yellow post does not wait for
    // children) — re-stages block 0 for the next epoch. Real time never
    // orders them; happens-before must flag the pair.
    let det = RaceDetector::new(5, 7);
    let det2 = det.clone();
    let report = SimCluster::new(spec(&[3, 2])).run(move |env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ag = ctx.allgather_init(env, 32, SyncScheme::Spin);
        let win_id = {
            let hw = ag.window().expect("window-backed handle");
            hw.win.id()
        };
        race::install(&det2, me);
        let block = vec![me as u8; 32];
        ag.start_allgather(env, &block);
        ag.wait(env);
        if me == 1 {
            // Epoch-1 in-place read of rank 0's block. No barrier before
            // the restart below: `env.barrier` rides the instrumented
            // SyncGroup and would (correctly) order the pair. Detection
            // is happens-before, not timing — both accesses get recorded
            // whichever order the threads actually run in.
            std::hint::black_box(ag.result_view(32).unwrap()[0]);
        }
        ag.start_allgather(env, &block); // rank 0 rewrites block 0
        ag.wait(env);
        race::uninstall();
        env.barrier(&w);
        ag.free(env);
        win_id
    });
    let win_id = report.outputs[0];
    let reports = det.reports();
    assert!(!reports.is_empty(), "the stale-epoch read must be flagged");
    let r = &reports[0];
    assert_eq!(r.win, win_id, "report names the offending window");
    assert_eq!(r.seed, 7, "report echoes the replay seed");
    let ranks = [r.first.rank, r.second.rank];
    assert!(ranks.contains(&0) && ranks.contains(&1), "offenders are ranks 0 and 1: {r}");
    assert!(
        r.first.write != r.second.write,
        "one side reads, the other writes: {r}"
    );
    for side in [&r.first, &r.second] {
        assert!(!side.stage.is_empty(), "each side names its stage: {r}");
    }
}
