//! Zero-copy data-plane tests:
//!
//! - steady-state execution of cached plans performs **zero pool-miss
//!   allocations** in the payload path (the PR-2 acceptance invariant);
//! - a hybrid allgather child copies O(msg) bytes per invocation, not
//!   O(p·msg);
//! - the emulated legacy data plane (`ClusterSpec::legacy_dataplane`)
//!   produces bit-identical results and *identical modeled virtual time*
//!   — zero-copy is a wall-clock optimization only.

use hympi::coll::{Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::SyncScheme;
use hympi::mpi::env::ProcEnv;
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::to_bytes;

fn spec(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// One application-shaped iteration: every collective in both flavors,
/// all through cached plans.
fn iteration(env: &mut ProcEnv, cache: &mut PlanCache, it: usize) {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let fl = Flavor::hybrid(SyncScheme::Spin);
    let msg = 2048usize;

    let mine = vec![(me + it) as u8; msg];
    let mut ag = vec![0u8; msg * p];
    cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut ag));
    cache.allgather(env, &w, fl, &mine, None);

    let mut bc = vec![it as u8; 4096];
    cache.bcast(env, &w, Flavor::Pure, 0, 4096, Some(&mut bc));
    cache.bcast(env, &w, fl, 0, 4096, Some(&mut bc));

    let vals: Vec<f64> = (0..256).map(|i| ((me + 1) * (i + it + 1)) as f64).collect();
    let mut ar = to_bytes(&vals).to_vec();
    cache.allreduce(env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut ar);
    let mut ar2 = to_bytes(&vals).to_vec();
    cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut ar2);
    assert_eq!(ar, ar2);

    let full: Vec<f64> = (0..64 * p).map(|e| ((me + 1) * (e + 1)) as f64).collect();
    let mut rs = vec![0u8; 64 * 8];
    cache.reduce_scatter(env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut rs);
    let mut rs2 = vec![0u8; 64 * 8];
    cache.reduce_scatter(env, &w, fl, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut rs2);
    assert_eq!(rs, rs2);
}

#[test]
fn steady_state_plans_are_pool_miss_free() {
    let report = SimCluster::new(spec(&[5, 3])).run(|env| {
        let w = env.world();
        let mut cache = PlanCache::new();
        // Prewarm the pool past the workload's high-water mark: hold a
        // generous number of slabs of every class the workload touches
        // simultaneously, then return them all. Afterwards a take can
        // only miss if concurrent demand exceeds this bound — which the
        // bounded-round collectives never do.
        {
            let mut held = Vec::new();
            let mut size = 64usize;
            while size <= 64 * 1024 {
                for _ in 0..64 {
                    held.push(env.take_buf(size));
                }
                size *= 2;
            }
        }
        // Warm-up: builds every plan (windows, params, tables) and runs
        // the pattern twice.
        for it in 0..2 {
            iteration(env, &mut cache, it);
            env.barrier(&w);
        }
        let misses_before = env.pool_misses();
        let hits_before = env.pool_hits();
        // Steady state: every invocation hits the plan cache and every
        // payload/scratch take hits the pool.
        for it in 2..10 {
            iteration(env, &mut cache, it);
            env.barrier(&w);
        }
        let misses_after = env.pool_misses();
        let hits_after = env.pool_hits();
        env.barrier(&w);
        cache.free(env);
        (misses_before, misses_after, hits_after - hits_before)
    });
    for (r, (m0, m1, hits)) in report.outputs.into_iter().enumerate() {
        assert_eq!(m1, m0, "rank {r}: steady-state plan execution must not allocate slabs");
        assert!(hits > 0, "rank {r}: steady-state traffic must recycle slabs (got {hits} hits)");
    }
}

/// The copy-counter workload: cached hybrid allgather with the result
/// left in the shared window (the paper's in-place sharing), measured on
/// the third invocation.
fn allgather_copy_counter(env: &mut ProcEnv) -> u64 {
    const MSG: usize = 16 * 1024;
    let w = env.world();
    let mut cache = PlanCache::new();
    let fl = Flavor::hybrid(SyncScheme::Spin);
    let mine = vec![7u8; MSG];
    for _ in 0..2 {
        cache.allgather(env, &w, fl, &mine, None);
    }
    env.barrier(&w);
    env.reset_copied_bytes();
    cache.allgather(env, &w, fl, &mine, None);
    let copied = env.copied_bytes();
    env.barrier(&w);
    cache.free(env);
    copied
}

#[test]
fn hybrid_allgather_children_copy_o_msg_not_o_p_msg() {
    const MSG: usize = 16 * 1024;
    let report = SimCluster::new(spec(&[5, 3])).run(allgather_copy_counter);
    let p = 8usize;
    for (r, &copied) in report.outputs.iter().enumerate() {
        let leader = r == 0 || r == 5; // lowest world rank per node
        if leader {
            // Leaders move their node block across the bridge: bounded by
            // a small multiple of the full vector, not per-rank fan-out.
            assert!(
                (copied as usize) < 3 * p * MSG,
                "leader rank {r} copied {copied} B (full vector is {})",
                p * MSG
            );
        } else {
            // Children store their own contribution and read the result
            // in place: O(msg), nowhere near O(p·msg).
            assert!(
                (copied as usize) <= 2 * MSG,
                "child rank {r} copied {copied} B, want O(msg) = {MSG}"
            );
        }
    }
    // The legacy plane's window materialization copies strictly more.
    let legacy = SimCluster::new(spec(&[5, 3]).with_legacy_dataplane(true)).run(allgather_copy_counter);
    let total_new: u64 = report.outputs.iter().sum();
    let total_legacy: u64 = legacy.outputs.iter().sum();
    assert!(
        total_legacy > total_new,
        "legacy plane must copy more ({total_legacy} vs {total_new})"
    );
}

/// Full-op workload returning (result bytes, final virtual clock).
fn parity_workload(env: &mut ProcEnv) -> (Vec<u8>, f64) {
    let w = env.world();
    let mut cache = PlanCache::new();
    let mut digest = Vec::new();
    for it in 0..3 {
        iteration(env, &mut cache, it);
    }
    // Fold one more hybrid round's results into the digest.
    let p = w.size();
    let me = w.rank();
    let fl = Flavor::hybrid(SyncScheme::Spin);
    let mine = vec![me as u8 + 1; 512];
    let mut ag = vec![0u8; 512 * p];
    cache.allgather(env, &w, fl, &mine, Some(&mut ag));
    digest.extend_from_slice(&ag);
    let vals = [(me + 2) as f64, (me * 3) as f64];
    let mut ar = to_bytes(&vals).to_vec();
    cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut ar);
    digest.extend_from_slice(&ar);
    env.barrier(&w);
    let v = env.vclock();
    cache.free(env);
    (digest, v)
}

#[test]
fn legacy_and_pooled_planes_agree_bitwise_and_in_virtual_time() {
    let pooled = SimCluster::new(spec(&[5, 3])).run(parity_workload);
    let legacy = SimCluster::new(spec(&[5, 3]).with_legacy_dataplane(true)).run(parity_workload);
    assert_eq!(pooled.outputs.len(), legacy.outputs.len());
    for (r, ((da, va), (db, vb))) in
        pooled.outputs.iter().zip(legacy.outputs.iter()).enumerate()
    {
        assert_eq!(da, db, "rank {r}: results must not depend on the data plane");
        assert!(
            (va - vb).abs() < 1e-9,
            "rank {r}: modeled virtual time must not depend on the data plane ({va} vs {vb})"
        );
    }
}
